"""Quickstart: the paper end-to-end on a laptop — parallel actors +
parallel learners + K-ary-sum-tree prioritized replay, DQN on CartPole,
through the executor API (runtime/executors.py).

    PYTHONPATH=src python examples/quickstart.py [--iterations 3000]

    # sharded runtime: 4 replay/learner shards on forced host devices
    PYTHONPATH=src python examples/quickstart.py --shards 4

    # async runtime: actors act on a 4-iteration-delayed parameter copy
    PYTHONPATH=src python examples/quickstart.py --executor async \\
        --publish-interval 4

    # sharded async: staggered shard clocks + staleness-weighted reduce
    PYTHONPATH=src python examples/quickstart.py --executor async \\
        --shards 4 --publish-interval 4 --max-staleness 1
"""

import argparse
import functools
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iterations", type=int, default=3000)
    ap.add_argument("--n-envs", type=int, default=8, help="parallel actors")
    ap.add_argument("--fanout", type=int, default=128,
                    help="sum-tree K (paper Fig. 9 sweep)")
    ap.add_argument("--backend", choices=("xla", "pallas"), default="xla",
                    help="TreeOps backend for buffer ops")
    ap.add_argument("--update-interval", type=int, default=1,
                    help="env steps per learn (paper ratio)")
    ap.add_argument("--shards", type=int, default=0,
                    help="run the ShardedExecutor over this many "
                         "host-platform device shards (0 = fused)")
    ap.add_argument("--executor", choices=("sync", "async"), default="sync",
                    help="async = actors act on a delayed parameter copy "
                         "(AsyncExecutor, DESIGN.md §5)")
    ap.add_argument("--publish-interval", type=int, default=4,
                    help="iterations between actor-copy republishes "
                         "(async executor; 1 = synchronous semantics)")
    ap.add_argument("--max-staleness", type=int, default=1,
                    help="drop a shard from the gradient reduce once its "
                         "acting copy ages past this many iterations "
                         "(sharded async executor)")
    args = ap.parse_args()

    if args.shards:
        # must be set before the first jax import; append so a user's
        # existing XLA_FLAGS are kept
        flag = f"--xla_force_host_platform_device_count={args.shards}"
        existing = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in existing:
            os.environ["XLA_FLAGS"] = f"{existing} {flag}".strip()

    import jax
    import jax.numpy as jnp

    from repro.agents.dqn import DQNConfig, make_dqn
    from repro.core.distributed import (ShardedPrioritizedReplay,
                                        ShardedReplayConfig)
    from repro.core.replay import PrioritizedReplay, ReplayConfig
    from repro.envs.classic import make_vec
    from repro.launch.mesh import data_mesh
    from repro.runtime.executors import (AsyncExecutor, FusedExecutor,
                                         ShardedExecutor)
    from repro.runtime.loop import LoopConfig

    env_fn = functools.partial(make_vec, "cartpole")
    spec, _, _ = env_fn(1)
    agent = make_dqn(spec, DQNConfig(double_q=True))
    example = {
        "obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "action": jnp.zeros((), jnp.int32),
        "reward": jnp.zeros(()),
        "next_obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "done": jnp.zeros(()),
    }
    cfg = LoopConfig(batch_size=64, warmup=500, epsilon=0.15,
                     update_interval=args.update_interval)

    if args.shards:
        mesh = data_mesh(args.shards)
        replay = ShardedPrioritizedReplay(
            ShardedReplayConfig(capacity_per_shard=50_000 // args.shards,
                                fanout=args.fanout, backend=args.backend),
            example)
        if args.executor == "async":
            ex = AsyncExecutor(agent, replay, env_fn, cfg, args.n_envs,
                               publish_interval=args.publish_interval,
                               max_staleness=args.max_staleness, mesh=mesh)
            print(f"async sharded executor: {args.shards} shards × "
                  f"{ex.n_envs_local} envs, publish every "
                  f"{args.publish_interval} iters, max staleness "
                  f"{args.max_staleness}")
        else:
            ex = ShardedExecutor(agent, replay, env_fn, cfg, args.n_envs,
                                 mesh)
            print(f"sharded executor: {args.shards} shards × "
                  f"{ex.n_envs_local} envs, batch/shard "
                  f"{cfg.batch_size // args.shards}")
    else:
        replay = PrioritizedReplay(
            ReplayConfig(capacity=50_000, fanout=args.fanout,
                         backend=args.backend), example)
        if args.executor == "async":
            ex = AsyncExecutor(agent, replay, env_fn, cfg, args.n_envs,
                               publish_interval=args.publish_interval)
            print(f"async fused executor: actors on a copy republished "
                  f"every {args.publish_interval} iters")
        else:
            ex = FusedExecutor(agent, replay, env_fn, cfg, args.n_envs)
            print("fused executor (single jit program)")
    print(f"ratio schedule: {ex.schedule} "
          f"(realized {ex.schedule.realized_ratio:.1f} env steps per learn)")

    state, hist = ex.train(args.iterations, jax.random.PRNGKey(0),
                           log_every=256)
    print(f"\nfinal mean episode return: "
          f"{float(hist['mean_episode_return'][-1]):.1f} "
          f"(CartPole solved ≈ 475; random ≈ 10)")


if __name__ == "__main__":
    main()
