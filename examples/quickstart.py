"""Quickstart: the paper end-to-end on a laptop — parallel actors +
parallel learners + K-ary-sum-tree prioritized replay, DQN on CartPole,
through the executor API (runtime/executors.py).

    PYTHONPATH=src python examples/quickstart.py [--iterations 3000]

    # sharded runtime: 4 replay/learner shards on forced host devices
    PYTHONPATH=src python examples/quickstart.py --shards 4

    # async runtime: actors act on a 4-iteration-delayed parameter copy
    PYTHONPATH=src python examples/quickstart.py --executor async \\
        --publish-interval 4

    # sharded async: staggered shard clocks + staleness-weighted reduce
    PYTHONPATH=src python examples/quickstart.py --executor async \\
        --shards 4 --publish-interval 4 --max-staleness 1

    # pod scale: 2×2 (pod × data) mesh, gradients reduce f32 inside a
    # pod and cross pods int8-EF-compressed (DESIGN.md §7)
    PYTHONPATH=src python examples/quickstart.py --pods 2 --shards 2 \\
        --compress-pod-reduce

    # planner-selected runtime (DESIGN.md §8): run the config the DSE
    # planner chose from measured throughput — first
    #   PYTHONPATH=src python -m benchmarks.run --emit-json out/ [--smoke]
    # then train straight from the emitted plan:
    PYTHONPATH=src python examples/quickstart.py --plan out/BENCH_plan.json
"""

import argparse
import functools
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--plan", default=None, metavar="BENCH_plan.json",
                    help="instantiate the executor/mesh a "
                         "runtime.planner plan selected (overrides "
                         "--shards/--pods/--executor/--publish-interval/"
                         "--max-staleness/--n-envs/--update-interval)")
    ap.add_argument("--iterations", type=int, default=3000)
    ap.add_argument("--n-envs", type=int, default=8, help="parallel actors")
    ap.add_argument("--fanout", type=int, default=128,
                    help="sum-tree K (paper Fig. 9 sweep)")
    ap.add_argument("--backend", choices=("xla", "pallas"), default="xla",
                    help="TreeOps backend for buffer ops")
    ap.add_argument("--update-interval", type=int, default=1,
                    help="env steps per learn (paper ratio)")
    ap.add_argument("--shards", type=int, default=0,
                    help="run the ShardedExecutor over this many "
                         "host-platform device shards (0 = fused); with "
                         "--pods this is the per-pod data-axis extent")
    ap.add_argument("--pods", type=int, default=0,
                    help="add a pod axis: a (pods × shards) two-axis mesh "
                         "(DESIGN.md §7)")
    ap.add_argument("--compress-pod-reduce", action="store_true",
                    help="int8 error-feedback compressed gradient reduce "
                         "across the pod axis (needs --pods)")
    ap.add_argument("--bf16-intra-pod", action="store_true",
                    help="cast the intra-pod (fast-axis) gradient reduce "
                         "to bf16 on the wire (needs --shards); the "
                         "injected error is the compress_error_norm "
                         "metric")
    ap.add_argument("--eager-replay", action="store_true",
                    help="disable the lazy-writing replay transactions "
                         "(three tree-propagation passes per iteration "
                         "instead of one — the pre-optimization baseline)")
    ap.add_argument("--executor", choices=("sync", "async"), default="sync",
                    help="async = actors act on a delayed parameter copy "
                         "(AsyncExecutor, DESIGN.md §5)")
    ap.add_argument("--publish-interval", type=int, default=4,
                    help="iterations between actor-copy republishes "
                         "(async executor; 1 = synchronous semantics)")
    ap.add_argument("--max-staleness", type=int, default=1,
                    help="drop a shard from the gradient reduce once its "
                         "acting copy ages past this many iterations "
                         "(sharded async executor)")
    args = ap.parse_args()

    plan = None
    if args.plan:
        # planner + plan loading are jax-free on purpose: the forced
        # device count must be known before the first jax import
        from repro.runtime.planner import load_plan

        plan = load_plan(args.plan)
        print(f"plan: {plan.describe()}")

    if args.pods and not args.shards:
        args.shards = 1                       # pods alone: P×1 mesh
    if args.compress_pod_reduce and not args.pods:
        ap.error("--compress-pod-reduce needs --pods (the compressed leg "
                 "crosses the pod axis)")
    if args.bf16_intra_pod and not args.shards and not args.plan:
        ap.error("--bf16-intra-pod needs --shards or a sharded --plan "
                 "(the fused path has no cross-shard reduce to cast)")
    n_devices = (plan.n_devices if plan
                 else args.shards * max(1, args.pods))
    if n_devices > 1:
        # must be set before the first jax import; append so a user's
        # existing XLA_FLAGS are kept
        flag = f"--xla_force_host_platform_device_count={n_devices}"
        existing = os.environ.get("XLA_FLAGS", "")
        if "xla_force_host_platform_device_count" not in existing:
            os.environ["XLA_FLAGS"] = f"{existing} {flag}".strip()

    import jax
    import jax.numpy as jnp

    from repro.agents.dqn import DQNConfig, make_dqn
    from repro.core.distributed import (ShardedPrioritizedReplay,
                                        ShardedReplayConfig)
    from repro.core.replay import PrioritizedReplay, ReplayConfig
    from repro.envs.classic import make_vec
    from repro.launch.mesh import data_mesh, pod_data_mesh
    from repro.runtime.executors import (AsyncExecutor, FusedExecutor,
                                         ShardedExecutor,
                                         executor_from_plan)
    from repro.runtime.loop import LoopConfig

    env_fn = functools.partial(make_vec, "cartpole")
    spec, _, _ = env_fn(1)
    agent = make_dqn(spec, DQNConfig(double_q=True))
    example = {
        "obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "action": jnp.zeros((), jnp.int32),
        "reward": jnp.zeros(()),
        "next_obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "done": jnp.zeros(()),
    }
    cfg = LoopConfig(batch_size=64, warmup=500, epsilon=0.15,
                     update_interval=args.update_interval,
                     lazy_replay=not args.eager_replay)
    intra_pod_dtype = "bf16" if args.bf16_intra_pod else None

    if plan:
        ex = executor_from_plan(plan, agent, env_fn, cfg, example,
                                fanout=args.fanout,
                                tree_backend=args.backend,
                                intra_pod_dtype=intra_pod_dtype)
        print(f"planner-selected {plan.backend} executor on "
              f"{plan.n_devices} device(s), {plan.n_envs} envs "
              f"(predicted {plan.predicted_env_steps_per_s:,.0f} "
              "env-steps/s)")
    elif args.shards:
        if args.pods:
            mesh = pod_data_mesh(args.pods, args.shards)
            axis_names = ("pod", "data")
        else:
            mesh = data_mesh(args.shards)
            axis_names = ("data",)
        n_cells = args.shards * max(1, args.pods)
        replay = ShardedPrioritizedReplay(
            ShardedReplayConfig(capacity_per_shard=50_000 // n_cells,
                                fanout=args.fanout, backend=args.backend,
                                axis_names=axis_names),
            example)
        mesh_desc = (f"{args.pods}×{args.shards} pod×data cells"
                     if args.pods else f"{args.shards} shards")
        fast_dtype = "bf16" if args.bf16_intra_pod else "f32"
        reduce_desc = (f"{fast_dtype} intra-pod + int8-EF cross-pod"
                       if args.compress_pod_reduce
                       else f"{fast_dtype} pmean")
        if args.executor == "async":
            ex = AsyncExecutor(agent, replay, env_fn, cfg, args.n_envs,
                               publish_interval=args.publish_interval,
                               max_staleness=args.max_staleness, mesh=mesh,
                               compress_pod_reduce=args.compress_pod_reduce,
                               intra_pod_dtype=intra_pod_dtype)
            print(f"async sharded executor: {mesh_desc} × "
                  f"{ex.n_envs_local} envs, publish every "
                  f"{args.publish_interval} iters, max staleness "
                  f"{args.max_staleness}, reduce {reduce_desc}")
        else:
            ex = ShardedExecutor(agent, replay, env_fn, cfg, args.n_envs,
                                 mesh,
                                 compress_pod_reduce=args.compress_pod_reduce,
                                 intra_pod_dtype=intra_pod_dtype)
            print(f"sharded executor: {mesh_desc} × "
                  f"{ex.n_envs_local} envs, batch/shard "
                  f"{cfg.batch_size // n_cells}, reduce {reduce_desc}")
    else:
        replay = PrioritizedReplay(
            ReplayConfig(capacity=50_000, fanout=args.fanout,
                         backend=args.backend), example)
        if args.executor == "async":
            ex = AsyncExecutor(agent, replay, env_fn, cfg, args.n_envs,
                               publish_interval=args.publish_interval)
            print("async fused executor: actors on a copy republished "
                  f"every {args.publish_interval} iters")
        else:
            ex = FusedExecutor(agent, replay, env_fn, cfg, args.n_envs)
            print("fused executor (single jit program)")
    print(f"ratio schedule: {ex.schedule} "
          f"(realized {ex.schedule.realized_ratio:.1f} env steps per learn)")

    state, hist = ex.train(args.iterations, jax.random.PRNGKey(0),
                           log_every=256)
    print("\nfinal mean episode return: "
          f"{float(hist['mean_episode_return'][-1]):.1f} "
          "(CartPole solved ≈ 475; random ≈ 10)")


if __name__ == "__main__":
    main()
