"""Quickstart: the paper end-to-end on a laptop — parallel actors +
parallel learners + K-ary-sum-tree prioritized replay, DQN on CartPole.

    PYTHONPATH=src python examples/quickstart.py [--iterations 3000]
"""

import argparse

import jax
import jax.numpy as jnp

from repro.agents.dqn import DQNConfig, make_dqn
from repro.core.replay import PrioritizedReplay, ReplayConfig
from repro.envs.classic import make_vec
from repro.runtime import loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iterations", type=int, default=3000)
    ap.add_argument("--n-envs", type=int, default=8, help="parallel actors")
    ap.add_argument("--fanout", type=int, default=128,
                    help="sum-tree K (paper Fig. 9 sweep)")
    ap.add_argument("--use-kernels", action="store_true",
                    help="route buffer ops through the Pallas kernels")
    args = ap.parse_args()

    spec, v_reset, v_step = make_vec("cartpole", args.n_envs)
    agent = make_dqn(spec, DQNConfig(double_q=True))
    replay = PrioritizedReplay(
        ReplayConfig(capacity=50_000, fanout=args.fanout,
                     use_kernels=args.use_kernels),
        {
            "obs": jnp.zeros((spec.obs_dim,), jnp.float32),
            "action": jnp.zeros((), jnp.int32),
            "reward": jnp.zeros(()),
            "next_obs": jnp.zeros((spec.obs_dim,), jnp.float32),
            "done": jnp.zeros(()),
        },
    )
    cfg = loop.LoopConfig(batch_size=64, warmup=500, epsilon=0.15)
    state, hist = loop.train(agent, replay, v_reset, v_step, cfg,
                             n_envs=args.n_envs, iterations=args.iterations,
                             key=jax.random.PRNGKey(0), log_every=256)
    print(f"\nfinal mean episode return: "
          f"{float(hist['mean_episode_return'][-1]):.1f} "
          f"(CartPole solved ≈ 475; random ≈ 10)")


if __name__ == "__main__":
    main()
