"""Continuous-batching actor serving — thin CLI over ``repro.serve``
(DESIGN.md §13): submit N random prompts, run the slot scheduler to
completion, report prefill and decode phases separately with EXACT
token accounting.

The seed version of this file timed ``gen - 1`` decode steps but
collected ``gen`` tokens into the throughput number; here every token
is attributed to exactly one phase — one prefill token per admission,
one decode token per busy slot per step — and the closed-form identity
``admissions + decoded_tokens == requests × gen`` is asserted before
anything is printed or emitted.

    PYTHONPATH=src python examples/serve_actor.py --arch granite_8b --smoke \
        --requests 8 --slots 4 --gen 16 --emit-json serve_report.json
"""

import argparse
import json
import sys

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--requests", type=int, default=8,
                    help="number of prompts to serve")
    ap.add_argument("--slots", type=int, default=4,
                    help="continuous-batching decode slots")
    ap.add_argument("--prompt-len", type=int, default=12,
                    help="max prompt length (lengths sampled 1..this)")
    ap.add_argument("--gen", type=int, default=16,
                    help="generated tokens per request")
    ap.add_argument("--buckets", default=None,
                    help="comma-separated prompt padding buckets "
                         "(default: prompt-len and its half)")
    ap.add_argument("--max-len", type=int, default=None,
                    help="KV cache length (default: prompt-len + gen)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--emit-json", default=None, metavar="FILE",
                    help="write the phase-separated serving report")
    args = ap.parse_args()

    import jax

    from repro.configs import get_config
    from repro.models import backbone
    from repro.serve import ActorServeConfig, ActorServer, SUPPORTED_FAMILIES

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.family not in SUPPORTED_FAMILIES:
        print(f"{cfg.name}: family {cfg.family!r} is not servable — the "
              f"continuous-batching engine needs a position-indexed KV "
              f"cache (supported: {', '.join(SUPPORTED_FAMILIES)})",
              file=sys.stderr)
        return 2

    max_len = args.max_len or (args.prompt_len + args.gen)
    if args.buckets:
        buckets = tuple(int(b) for b in args.buckets.split(","))
    else:
        buckets = tuple(sorted({max(1, args.prompt_len // 2),
                                args.prompt_len}))
    params = backbone.init_params(cfg, jax.random.PRNGKey(args.seed))
    server = ActorServer(cfg, params, ActorServeConfig(
        slots=args.slots, max_len=max_len, buckets=buckets,
        max_new_tokens=args.gen))

    rng = np.random.RandomState(args.seed)
    lens = rng.randint(1, args.prompt_len + 1, size=args.requests)
    handles = [server.submit(rng.randint(0, cfg.vocab_size, size=int(n)))
               for n in lens]
    server.drain(timeout=600)
    completions = [h.result(0) for h in handles]

    s = server.stats()
    # exact accounting: every generated token belongs to exactly one phase
    generated = sum(len(c.tokens) for c in completions)
    assert generated == args.requests * args.gen, (generated, args.requests,
                                                   args.gen)
    assert s["generated_tokens"] == generated, (s["generated_tokens"],
                                                generated)
    prefill_tokens = s["admissions"]          # one first-token per prefill
    decode_tokens = s["decoded_tokens"]
    prefill_s, decode_s = s["prefill_s"], s["decode_s"]

    print(f"{cfg.name}: served {args.requests} requests × {args.gen} tokens "
          f"on {args.slots} slots (buckets {buckets}, "
          f"{s['prime_compiles']} prefill compiles, "
          f"{s['decode_compiles']} decode compile)")
    print(f"prefill: {prefill_tokens} prompts "
          f"({int(np.sum(lens))} prompt tokens) in {prefill_s*1e3:.1f} ms "
          f"— {prefill_tokens/prefill_s:.1f} first-tokens/s"
          if prefill_s > 0 else "prefill: instantaneous")
    print(f"decode:  {s['steps']} steps, {decode_tokens} tokens in "
          f"{decode_s*1e3:.1f} ms — {decode_tokens/decode_s:.1f} tok/s"
          if decode_s > 0 else "decode: no steps")
    if "latency_p50_ms" in s:
        print(f"latency: p50 {s['latency_p50_ms']:.1f} ms, "
              f"p99 {s['latency_p99_ms']:.1f} ms")
    print("sample tokens:", completions[0].tokens[:16])

    if args.emit_json:
        report = {
            "arch": cfg.name,
            "requests": args.requests,
            "slots": args.slots,
            "gen": args.gen,
            "buckets": list(buckets),
            "prefill": {
                "prompts": int(prefill_tokens),
                "prompt_tokens": int(np.sum(lens)),
                "first_tokens": int(prefill_tokens),
                "seconds": round(prefill_s, 6),
            },
            "decode": {
                "steps": int(s["steps"]),
                "tokens": int(decode_tokens),
                "seconds": round(decode_s, 6),
                "tokens_per_s": (round(decode_tokens / decode_s, 2)
                                 if decode_s > 0 else None),
            },
            "generated_tokens": int(generated),
            "latency_p50_ms": s.get("latency_p50_ms"),
            "latency_p99_ms": s.get("latency_p99_ms"),
            "prime_compiles": int(s["prime_compiles"]),
        }
        with open(args.emit_json, "w") as f:
            json.dump(report, f, indent=2)
            f.write("\n")
        print(f"# wrote {args.emit_json}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
