"""Batched actor serving (deliverable b): the paper's act() at LM scale —
prefill a batch of prompts, then KV-cached greedy decode (serve_step),
reporting per-step latency and tokens/s.

    PYTHONPATH=src python examples/serve_actor.py --arch granite_8b --smoke
"""

import argparse
import functools
import time

import jax
import jax.numpy as jnp

from repro.agents import token_dqn
from repro.configs import get_config
from repro.models import backbone
from repro.models.config import NO_SHARDING


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite_8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=args.smoke)
    key = jax.random.PRNGKey(0)
    params = backbone.init_params(cfg, key)
    max_len = args.prompt_len + args.gen

    extra = None
    s_text = args.prompt_len
    if cfg.family == "vlm":
        s_text = max(4, args.prompt_len - cfg.num_patch_tokens)
        extra = jax.random.normal(
            key, (args.batch, cfg.num_patch_tokens, cfg.d_model)) * 0.1
    if cfg.family == "audio":
        extra = jax.random.normal(
            key, (args.batch, cfg.encoder_seq, cfg.d_model)) * 0.1
    prompts = jax.random.randint(key, (args.batch, s_text), 0, cfg.vocab_size)

    prefill = jax.jit(functools.partial(backbone.prefill, cfg, NO_SHARDING),
                      static_argnames=("max_len",))
    serve = jax.jit(functools.partial(token_dqn.serve_step, cfg, NO_SHARDING),
                    donate_argnums=(1,))

    t0 = time.time()
    logits, cache = prefill(params, prompts, max_len=max_len,
                            extra_embeds=extra)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    print(f"{cfg.name}: prefill {args.batch}×{s_text} in {t_prefill*1e3:.1f} ms")

    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    outs = [tok]
    # first call compiles
    action, cache = serve(params, cache, tok)
    tok = action[:, None].astype(jnp.int32)
    t0 = time.time()
    for _ in range(args.gen - 1):
        action, cache = serve(params, cache, tok)
        tok = action[:, None].astype(jnp.int32)
        outs.append(tok)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    steps = args.gen - 1
    print(f"decode: {steps} steps × {args.batch} seqs — "
          f"{dt/steps*1e3:.2f} ms/step, {steps*args.batch/dt:.1f} tok/s")
    gen = jnp.concatenate(outs, axis=1)
    print("sample tokens:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
