"""End-to-end driver (deliverable b): train a ~100M-param LM-backbone
token-Q learner for a few hundred steps on the token MDP, with the full
paper pipeline — parallel actors collecting trajectory segments into the
prioritized replay buffer, the learner sampling with PER weights,
priorities updated from TD errors, checkpointing every N steps.

The collection/consumption ratio is governed by the same
``RatioSchedule`` the executors use (runtime/loop.py): ``--update-interval``
is honored in collected segments per learner update, and the buffer's
tree ops dispatch through the TreeOps backend (``--backend pallas``).

    PYTHONPATH=src python examples/train_token_dqn.py --steps 300
"""

import argparse
import dataclasses
import functools
import time

import jax
import jax.numpy as jnp

from repro.agents import token_dqn
from repro.checkpoint.manager import CheckpointManager
from repro.core.replay import PrioritizedReplay, ReplayConfig
from repro.envs.token_mdp import TokenMDPSpec, make
from repro.models.config import ModelConfig, NO_SHARDING
from repro.optim import adam
from repro.runtime.loop import LoopConfig, RatioSchedule

# ~100M params: 8L × d512 × vocab 8192 GQA backbone
CFG_100M = ModelConfig(
    name="token-dqn-100m", family="dense", num_layers=8, d_model=512,
    num_heads=8, num_kv_heads=4, d_ff=2048, vocab_size=8192,
    dtype="float32", remat=False,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=64, help="segment length")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--n-envs", type=int, default=32)
    ap.add_argument("--small", action="store_true", help="tiny debug model")
    ap.add_argument("--ckpt-dir", default="/tmp/token_dqn_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--update-interval", type=int, default=32,
                    help="collected segments per learner update")
    ap.add_argument("--learns-per-step", type=int, default=1)
    ap.add_argument("--backend", choices=("xla", "pallas"), default="xla",
                    help="TreeOps backend for buffer ops")
    args = ap.parse_args()

    cfg = CFG_100M
    if args.small:
        cfg = dataclasses.replace(cfg, num_layers=2, d_model=64, num_heads=4,
                                  num_kv_heads=2, d_ff=128, vocab_size=256)
    tcfg = token_dqn.TokenDQNConfig(
        gamma=0.9, accum=1, opt=adam.AdamConfig(lr=1e-4))
    key = jax.random.PRNGKey(0)
    state = token_dqn.init_train_state(cfg, tcfg, key)
    n_params = sum(x.size for x in jax.tree.leaves(state.params))
    print(f"model: {cfg.name}  params: {n_params/1e6:.1f}M")

    # token-MDP actors: each env emits one token transition per step;
    # a segment of --seq steps becomes one replay item.
    mdp = TokenMDPSpec(vocab=cfg.vocab_size)
    reset, step_env, optimal = make(mdp, jax.random.fold_in(key, 1), args.n_envs)
    env_state, obs = reset(jax.random.fold_in(key, 2))

    example = {
        "tokens": jnp.zeros((args.seq,), jnp.int32),
        "actions": jnp.zeros((args.seq,), jnp.int32),
        "rewards": jnp.zeros((args.seq,), jnp.float32),
        "dones": jnp.zeros((args.seq,), jnp.float32),
    }
    replay = PrioritizedReplay(
        ReplayConfig(capacity=4096, fanout=128, backend=args.backend), example)
    rst = replay.init()
    schedule = RatioSchedule.from_config(
        LoopConfig(update_interval=args.update_interval,
                   learns_per_step=args.learns_per_step),
        env_steps_per_iter=args.n_envs)
    print(f"ratio schedule: learn every {schedule.period} collect(s), "
          f"{schedule.learns} update(s) per event "
          f"({schedule.realized_ratio:.0f} segments per update)")

    @jax.jit
    def collect(params, env_state, obs, key):
        """Actors: greedy-ε act over a segment (teacher-forcing the model's
        own context), producing (n_envs, seq) transition segments."""
        def one(carry, i):
            env_state, obs, ctx = carry
            k = jax.random.fold_in(key, i)
            logits = token_dqn.backbone.forward(cfg, NO_SHARDING, params,
                                                ctx)[:, -1]
            greedy = jnp.argmax(logits, -1)
            rand = jax.random.randint(k, greedy.shape, 0, cfg.vocab_size)
            act = jnp.where(jax.random.uniform(k, greedy.shape) < 0.1,
                            rand, greedy)
            env_state2, obs2, rew, done = step_env(env_state, act, k)
            ctx2 = jnp.concatenate([ctx[:, 1:], obs2[:, None]], axis=1)
            return (env_state2, obs2, ctx2), (obs, act, rew, done)

        ctx0 = jnp.tile(obs[:, None], (1, 8))
        (env_state, obs, _), (toks, acts, rews, dones) = jax.lax.scan(
            one, (env_state, obs, ctx0), jnp.arange(args.seq))
        seg = {
            "tokens": toks.T, "actions": acts.T,
            "rewards": rews.T, "dones": dones.T.astype(jnp.float32),
        }
        return env_state, obs, seg

    train_step = jax.jit(functools.partial(
        token_dqn.train_step, cfg, NO_SHARDING, tcfg), donate_argnums=(0,))

    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    start, state = mgr.restore_latest(state)
    if start is not None:
        print(f"resumed from checkpoint step {start}")

    t0 = time.time()
    metrics = {"loss": float("nan")}
    # checkpoints are labeled by collect iteration, which (with a ratio
    # schedule) is no longer equal to state.step (learner-update count)
    for it in range(start or 0, args.steps):
        key, kc, ks = jax.random.split(key, 3)
        env_state, obs, seg = collect(state.params, env_state, obs, kc)
        rst = replay.insert(rst, seg)
        if it % schedule.period == 0:
            for j in range(schedule.learns):
                idx, items, w = replay.sample(
                    rst, jax.random.fold_in(ks, j), args.batch)
                batch = dict(items, is_weights=w)
                state, metrics, tds = train_step(state, batch)
                rst = replay.update_priorities(rst, idx, tds)
        if it % 20 == 0:
            r = float(jnp.mean(seg["rewards"]))
            print(f"step {it:4d} loss {float(metrics['loss']):.4f} "
                  f"actor-reward {r:.3f} (optimal {optimal():.3f}) "
                  f"buffer {int(rst.count)}")
        if args.ckpt_every and it and it % args.ckpt_every == 0:
            mgr.save_async(it, state)
    mgr.wait()
    mgr.save(args.steps, state)
    print(f"done in {time.time()-t0:.0f}s; checkpoint at {args.ckpt_dir}")


if __name__ == "__main__":
    main()
