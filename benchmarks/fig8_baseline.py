"""Fig. 8 — parallel framework vs sequential baseline.

The paper measures convergence time vs RLlib at equal core counts.  Here
the baseline is the sequential reference implementation (1 actor, python
-stepped loop, per-item buffer ops — what a global lock serializes to),
and ours is the fused parallel_step with vectorized actors + batched
lazy-write buffer ops.  We report steady-state environment-steps/second
and derived speedup at matched learn ratio (update_interval=1), plus a
convergence check (CartPole return) for the derived column.
"""

import functools
import time

import jax
import jax.numpy as jnp

from repro.agents.dqn import DQNConfig, make_dqn
from repro.core.replay import PrioritizedReplay, ReplayConfig
from repro.envs.classic import make_vec
from repro.runtime import loop
from repro.runtime.executors import FusedExecutor


def transition_example(spec):
    return {
        "obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "action": jnp.zeros((), jnp.int32),
        "reward": jnp.zeros(()),
        "next_obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "done": jnp.zeros(()),
    }


def _make_executor(n_envs: int, scan_chunk: int = 20) -> FusedExecutor:
    env_fn = functools.partial(make_vec, "cartpole")
    spec, _, _ = env_fn(1)
    agent = make_dqn(spec, DQNConfig())
    replay = PrioritizedReplay(ReplayConfig(capacity=50_000, fanout=128),
                               transition_example(spec))
    cfg = loop.LoopConfig(batch_size=64, warmup=128, epsilon=0.1)
    return FusedExecutor(agent, replay, env_fn, cfg, n_envs,
                         scan_chunk=scan_chunk)


def throughput(n_envs: int, iters: int = 200, fused_scan: bool = True) -> float:
    try:
        from benchmarks.fig10_scalability import _time_executor
    except ImportError:  # run directly as a script: benchmarks/ is sys.path[0]
        from fig10_scalability import _time_executor

    ex = _make_executor(n_envs)
    if fused_scan:
        return _time_executor(ex, iters)
    # sequential baseline: python-stepped, one env transition per call
    st = ex.init(jax.random.PRNGKey(0))
    jstep = jax.jit(ex.step)
    st, _ = jstep(st)
    jax.block_until_ready(st.obs)
    t0 = time.perf_counter()
    for _ in range(iters):
        st, _ = jstep(st)
    jax.block_until_ready(st.obs)
    dt = time.perf_counter() - t0
    return n_envs * iters / dt


def run(csv=True):
    rows = []
    base = throughput(1, fused_scan=False)        # sequential baseline
    rows.append(("fig8/sequential_1env", 1e6 / base, 1.0))
    for n in (4, 8, 16):
        t = throughput(n)
        rows.append((f"fig8/parallel_{n}env", 1e6 / t, t / base))
    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.2f},{derived:.2f}")
    return rows


if __name__ == "__main__":
    run()
