"""Fig. 12 / §V-D — design-space exploration on profiled curves.

Profiles the actual throughput of (a) data collection vs actor lanes and
(b) learning vs learner batch lanes on this host, then solves Eq. 5
through the runtime planner (``runtime/planner.py`` — the same code path
``benchmarks/run.py --emit-json`` uses to choose the executor config),
so the paper figure is produced by the production solver, not a copy.
CSV derived column = realized collection/consumption ratio of the chosen
allocation.
"""

import jax
import jax.numpy as jnp

from repro.agents.dqn import DQNConfig, make_dqn
from repro.envs.classic import make_vec
from repro.runtime import dse, planner


def actor_throughput(lanes: int) -> float:
    spec, v_reset, v_step = make_vec("cartpole", lanes)
    agent = make_dqn(spec, DQNConfig())
    ast = agent.init(jax.random.PRNGKey(0))
    env_state, obs = v_reset(jax.random.PRNGKey(1))
    act = jax.jit(agent.act)
    step = jax.jit(v_step)

    def fn():
        nonlocal env_state, obs
        for i in range(10):
            key = jax.random.fold_in(jax.random.PRNGKey(2), i)
            a = act(ast, obs, key, 0.1)
            env_state, obs, r, d, t = step(env_state, a, key)
        jax.block_until_ready(obs)

    return dse.measure_throughput(fn, 10 * lanes)


def learner_throughput(lanes: int) -> float:
    """lanes × 32 = learner batch per update."""
    spec, _, _ = make_vec("cartpole", 1)
    agent = make_dqn(spec, DQNConfig())
    ast = agent.init(jax.random.PRNGKey(0))
    b = 32 * lanes
    batch = {
        "obs": jnp.zeros((b, 4)), "action": jnp.zeros((b,), jnp.int32),
        "reward": jnp.ones((b,)), "next_obs": jnp.zeros((b, 4)),
        "done": jnp.zeros((b,)),
    }
    learn = jax.jit(agent.learn)

    def fn():
        nonlocal ast
        for _ in range(10):
            ast, _, _ = learn(ast, batch, jnp.ones((b,)))
        jax.block_until_ready(ast.params[0]["w"])

    return dse.measure_throughput(fn, 10 * b)


def run(csv=True):
    lanes = [1, 2, 4, 8]
    fa = dse.profile_curve(actor_throughput, lanes)
    fl = dse.profile_curve(learner_throughput, lanes)
    rows = []
    for x in lanes:
        rows.append((f"fig12/actor_curve_{x}", 1e6 / fa[x], fa[x]))
        rows.append((f"fig12/learner_curve_{x}", 1e6 / fl[x], fl[x]))
    for ratio in (1.0, 4.0):
        res = planner.solve_lanes(fa, fl, total=8, update_interval=ratio)
        rows.append((f"fig12/solve_ui{ratio:g}_xa{res.x_actor}_xl{res.x_learner}",
                     0.0, res.ratio))
    # the full planner on the same curves (no BENCH points profiled here
    # → the curve-only fused fallback): the figure's "chosen config" row
    pc = planner.plan(actor_curve=fa, learner_curve=fl,
                      total_lanes=8, update_interval=1, source="fig12")
    rows.append((f"fig12/plan_{pc.backend}_envs{pc.n_envs}",
                 0.0, pc.predicted_env_steps_per_s))
    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.2f},{derived:.2f}")
    return rows


if __name__ == "__main__":
    run()
