"""Perf-regression gate: diff fresh BENCH json against the committed
repo-root baselines.

    PYTHONPATH=src python -m benchmarks.compare out/ [--baseline-dir .]

Points are matched on their identity fields (backend, shard/pod counts,
async knobs — everything except the measured throughput); a fresh point
slower than its baseline by more than its tolerance fails the gate
(exit 1).  The tolerance is per point: ``THRESHOLD`` plus the larger
recorded ``rel_spread`` of the two measurements — a point whose
median-of-N repeats disperse widely (noisy multi-process gang points,
cold CI runners) gets exactly that much extra slack, while tight
points keep the tight gate.  Missing points on either side are
tolerated with a note — sweeps grow and shrink across PRs, and a
baseline measured on different hardware only gates *relative*
regressions on matching points — but a baseline file whose points
*all* fail to match (an identity-field rename de-matching the whole
sweep) is a hard failure: a gate that matched nothing checked
nothing.  CI runs this as a **blocking** step
(the bench-smoke job fails on regression).

THRESHOLD is the one place the base tolerance lives — CI, the cron
sweep and local runs all read it from here (override per-run with
--threshold).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, Iterable, List, Tuple

# >30% env-steps/s regression on a matching point fails the gate.
# Generous on purpose: CI runners are noisy; this catches structural
# slowdowns (a backend falling off a cliff), not jitter.
THRESHOLD = 0.30

BENCH_FILES = ("BENCH_fig9.json", "BENCH_fig10.json", "BENCH_replay.json",
               "BENCH_serve.json", "BENCH_actor.json")

# fields that identify a point (everything but the measurements); the
# median-of-N dispersion record (repeats/rel_spread) is measurement-side
# so old baselines without it still match.  samples_per_s and
# realized_spi are the serve figure's secondary measurements, and the
# actor figure's latencies/swap counts are likewise secondary — each
# gate compares its figure's primary metric only.
_MEASUREMENT_FIELDS = {"env_steps_per_s", "replay_ops_per_s",
                       "inserts_per_s", "speedup_vs_sync",
                       "repeats", "rel_spread",
                       "samples_per_s", "realized_spi", "recovery_s",
                       "requests_per_s", "p50_ms", "p99_ms",
                       "p99_before_swap_ms", "p99_after_swap_ms",
                       "param_swaps"}


def point_key(point: dict) -> Tuple:
    """Identity of a measured point: every non-measurement field,
    sorted — robust to schema growth (a new identity knob simply makes
    old points unmatched, which is tolerated)."""
    return tuple(sorted(
        (k, v) for k, v in point.items() if k not in _MEASUREMENT_FIELDS))


def _load_points(path: str) -> Tuple[Dict[Tuple, Tuple[float, float]], str]:
    """key → (measured rate, recorded rel_spread) per point; points
    without a dispersion record get spread 0 (no extra slack)."""
    with open(path) as f:
        payload = json.load(f)
    # each payload names its own measured rate (schema.FIGURE_METRICS)
    metric = payload.get("metric", "env_steps_per_s")
    return ({point_key(p): (float(p[metric]),
                            float(p.get("rel_spread", 0.0)))
             for p in payload.get("points", ())}, metric)


def compare_points(baseline: Dict[Tuple, Tuple[float, float]],
                   fresh: Dict[Tuple, Tuple[float, float]],
                   threshold: float, metric: str = "env_steps_per_s"
                   ) -> Tuple[List[str], List[str]]:
    """Returns (regressions, notes) — regressions non-empty fails the
    gate.  Each matched point fails below ``threshold + max(baseline
    rel_spread, fresh rel_spread)``: the recorded median-of-N dispersion
    widens that point's tolerance, so a noisy measurement can't trip the
    gate on jitter its own repeats already exhibited."""
    regressions, notes = [], []
    for key, (base_v, base_rs) in sorted(baseline.items()):
        label = ", ".join(f"{k}={v}" for k, v in key)
        if key not in fresh:
            notes.append(f"baseline-only point (skipped): {label}")
            continue
        fresh_v, fresh_rs = fresh[key]
        delta = (fresh_v - base_v) / base_v
        tol = threshold + max(base_rs, fresh_rs)
        line = (f"{label}: {base_v:,.0f} → {fresh_v:,.0f} {metric} "
                f"({delta:+.1%}, tol -{tol:.0%})")
        if delta < -tol:
            regressions.append(line)
        else:
            notes.append(line)
    for key in sorted(set(fresh) - set(baseline)):
        label = ", ".join(f"{k}={v}" for k, v in key)
        notes.append(f"new point (no baseline): {label}")
    return regressions, notes


def compare_dirs(fresh_dir: str, baseline_dir: str, threshold: float,
                 files: Iterable[str] = BENCH_FILES) -> int:
    """Diff every BENCH file present in both dirs; returns the number of
    regressed points (0 = gate passes)."""
    total_regressions = 0
    compared_any = False
    for name in files:
        fresh_path = os.path.join(fresh_dir, name)
        base_path = os.path.join(baseline_dir, name)
        if not os.path.exists(fresh_path):
            print(f"-- {name}: no fresh measurement (skipped)")
            continue
        if not os.path.exists(base_path):
            print(f"-- {name}: no committed baseline (skipped)")
            continue
        compared_any = True
        baseline_pts, metric = _load_points(base_path)
        fresh_pts, _ = _load_points(fresh_path)
        regressions, notes = compare_points(baseline_pts, fresh_pts,
                                            threshold, metric)
        print(f"-- {name} (fail below -{threshold:.0%}):")
        for line in notes:
            print(f"   {line}")
        for line in regressions:
            print(f"   REGRESSION {line}")
        matched = len(set(baseline_pts) & set(fresh_pts))
        if baseline_pts and not matched:
            # an identity-field change (e.g. a new sweep env count) can
            # de-match every point at once, which would make the gate
            # vacuously green exactly when it matters most — a committed
            # baseline with zero matching fresh points is a hard failure,
            # not a note
            print(f"   FAIL: 0 matching points between baseline and "
                  f"fresh {name} — the gate checked nothing; "
                  "re-commit baselines from a fresh --emit-json run")
            total_regressions += 1
        total_regressions += len(regressions)
    if not compared_any:
        print("no BENCH file present on both sides — nothing gated")
    return total_regressions


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("fresh_dir",
                    help="directory with freshly emitted BENCH json "
                         "(benchmarks/run.py --emit-json)")
    ap.add_argument("--baseline-dir", default=".",
                    help="directory with the committed baselines "
                         "(default: repo root)")
    ap.add_argument("--threshold", type=float, default=THRESHOLD,
                    help="relative env-steps/s drop that fails "
                         f"(default {THRESHOLD})")
    args = ap.parse_args()
    n = compare_dirs(args.fresh_dir, args.baseline_dir, args.threshold)
    if n:
        print(f"FAIL: {n} regressed point(s) beyond "
              f"-{args.threshold:.0%}", file=sys.stderr)
        return 1
    print("perf gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
