"""Fig. 11 — speedup from plugging our replay buffer into an existing
trainer loop.

The paper swaps its C++ buffer into tianshou/PFRL/rlpyt.  The analogue
here: a fixed host-driven DQN trainer whose buffer is either (a) a naive
numpy prioritized buffer (O(N) proportional sampling via np.random.choice,
per-item priority updates — what pure-python RL libs do), or (b) our
K-ary sum-tree buffer (batched, jitted).  Same agent, same env steps;
derived column = naive_time / ours_time per trainer iteration."""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.agents.dqn import DQNConfig, make_dqn
from repro.core.replay import PrioritizedReplay, ReplayConfig
from repro.envs.classic import make_vec


class NaiveNumpyPER:
    """Reference for what a pure-python library's PER does (paper §VI-F)."""

    def __init__(self, capacity, obs_dim, alpha=0.6):
        self.capacity, self.alpha = capacity, alpha
        self.pri = np.zeros(capacity, np.float64)
        self.obs = np.zeros((capacity, obs_dim), np.float32)
        self.action = np.zeros(capacity, np.int64)
        self.reward = np.zeros(capacity, np.float32)
        self.next_obs = np.zeros((capacity, obs_dim), np.float32)
        self.done = np.zeros(capacity, np.float32)
        self.head = self.count = 0
        self.max_pri = 1.0

    def insert(self, obs, action, reward, next_obs, done):
        for i in range(len(action)):                 # per-item, like CPython
            j = self.head
            self.obs[j], self.action[j] = obs[i], action[i]
            self.reward[j], self.next_obs[j] = reward[i], next_obs[i]
            self.done[j] = done[i]
            self.pri[j] = self.max_pri
            self.head = (self.head + 1) % self.capacity
            self.count = min(self.count + 1, self.capacity)

    def sample(self, batch, beta=0.4):
        p = self.pri[: self.count]
        prob = p / p.sum()                            # O(N) every call
        idx = np.random.choice(self.count, batch, p=prob)
        w = (self.count * prob[idx]) ** (-beta)
        w = w / w.max()
        return idx, {
            "obs": self.obs[idx], "action": self.action[idx],
            "reward": self.reward[idx], "next_obs": self.next_obs[idx],
            "done": self.done[idx],
        }, w

    def update(self, idx, td):
        for i, t in zip(idx, td):                     # per-item updates
            self.pri[i] = (abs(t) + 1e-6) ** self.alpha
            self.max_pri = max(self.max_pri, self.pri[i])


def trainer_iteration_time(use_ours: bool, capacity=100_000, iters=60) -> float:
    n_envs = 8
    spec, v_reset, v_step = make_vec("cartpole", n_envs)
    agent = make_dqn(spec, DQNConfig())
    ast = agent.init(jax.random.PRNGKey(0))
    env_state, obs = v_reset(jax.random.PRNGKey(1))
    learn = jax.jit(agent.learn)
    act = jax.jit(agent.act)

    if use_ours:
        ex = {"obs": jnp.zeros((4,)), "action": jnp.zeros((), jnp.int32),
              "reward": jnp.zeros(()), "next_obs": jnp.zeros((4,)),
              "done": jnp.zeros(())}
        rb = PrioritizedReplay(ReplayConfig(capacity=capacity, fanout=128), ex)
        rst = rb.init()
        insert = jax.jit(rb.insert)
        sample = jax.jit(lambda s, k: rb.sample(s, k, 64))
        update = jax.jit(rb.update_priorities)
    else:
        rb = NaiveNumpyPER(capacity, 4)

    def one_iter(i, ast, rst, env_state, obs):
        key = jax.random.fold_in(jax.random.PRNGKey(2), i)
        a = act(ast, obs, key, 0.1)
        env_state, obs2, rew, done, true_next = v_step(env_state, a, key)
        tr = {"obs": obs, "action": a, "reward": rew,
              "next_obs": true_next, "done": done.astype(jnp.float32)}
        if use_ours:
            rst = insert(rst, tr)
            idx, items, w = sample(rst, key)
            ast, _, td = learn(ast, items, w)
            rst = update(rst, idx, td)
        else:
            rb.insert(np.asarray(tr["obs"]), np.asarray(tr["action"]),
                      np.asarray(tr["reward"]), np.asarray(tr["next_obs"]),
                      np.asarray(tr["done"]))
            idx, items, w = rb.sample(64)
            ast, _, td = learn(ast, jax.tree.map(jnp.asarray, items),
                               jnp.asarray(w.astype(np.float32)))
            rb.update(idx, np.asarray(td))
        return ast, rst, env_state, obs2

    rst = rst if use_ours else None
    # warmup buffer + jit
    for i in range(12):
        ast, rst, env_state, obs = one_iter(i, ast, rst, env_state, obs)
    jax.block_until_ready(obs)
    t0 = time.perf_counter()
    for i in range(iters):
        ast, rst, env_state, obs = one_iter(100 + i, ast, rst, env_state, obs)
    jax.block_until_ready(obs)
    return (time.perf_counter() - t0) / iters


def run(csv=True):
    rows = []
    for cap in (10_000, 100_000):
        naive = trainer_iteration_time(False, cap)
        ours = trainer_iteration_time(True, cap)
        rows.append((f"fig11/naive_N{cap}", naive * 1e6, 1.0))
        rows.append((f"fig11/ours_N{cap}", ours * 1e6, naive / ours))
    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived:.2f}")
    return rows


if __name__ == "__main__":
    run()
