"""Fig. 10 — scalability of DQN/DDPG/SAC vs parallel-actor count.

The paper scales CPU cores; the JAX adaptation scales vectorized actor
lanes (the same resource axis the DSE allocates).  Reports env-steps/s
per algorithm at 1/2/4/8/16 lanes and derived speedup vs 1 lane, through
the FusedExecutor.

A second mode sweeps *runtime shards*: ``--shards 1,2,4`` re-launches
this script in subprocesses with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` (the flag must be
set before jax initializes) and times the ShardedExecutor — DQN through
the sharded replay + psum'd learner — at each shard count.

A third mode measures the **wall-clock** arm (``--wall-clock``,
DESIGN.md §10): each point is a real multi-process gang launched
through ``launch/multiprocess.py`` — separate OS processes, one XLA
client each, gloo collectives over real process boundaries — timing
the same DQN/CartPole workload as the emulated arms (median-of-N with
``rel_spread`` inside the worker).  These land in BENCH_fig10.json as
``backend="wallclock"`` points carrying ``n_procs``/``overlapped``/
``update_interval`` identity fields, so the runtime planner can prefer
them over the emulated measurements of the same config.
"""

import argparse
import functools
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp

from repro.agents.ddpg import DDPGConfig, make_ddpg
from repro.agents.dqn import DQNConfig, make_dqn
from repro.agents.sac import SACConfig, make_sac
from repro.core.replay import PrioritizedReplay, ReplayConfig
from repro.envs.classic import make_vec
from repro.runtime import loop
from repro.runtime.executors import FusedExecutor


def example(spec):
    return {
        "obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "action": (jnp.zeros((), jnp.int32) if spec.discrete
                   else jnp.zeros((spec.action_dim,), jnp.float32)),
        "reward": jnp.zeros(()),
        "next_obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "done": jnp.zeros(()),
    }


ALGOS = {
    "dqn": ("cartpole", lambda s: make_dqn(s, DQNConfig())),
    "ddpg": ("pendulum", lambda s: make_ddpg(s, DDPGConfig())),
    "sac": ("pendulum", lambda s: make_sac(s, SACConfig())),
}


def _time_executor_stats(ex, iters: int, repeats=None):
    """(median env-steps/s, rel_spread) of a warmed executor over
    ``repeats`` passes of ``iters`` iterations (benchmarks/timing.py)."""
    from benchmarks.timing import REPEATS, median_with_spread

    st = ex.init(jax.random.PRNGKey(0))
    st, _ = ex.run_chunk(st)
    jax.block_until_ready(st.obs)
    n_chunks = max(1, iters // ex.scan_chunk)
    state = [st]

    def probe():
        t0 = time.perf_counter()
        for _ in range(n_chunks):
            state[0], _ = ex.run_chunk(state[0])
        jax.block_until_ready(state[0].obs)
        dt = time.perf_counter() - t0
        return ex.n_envs * ex.scan_chunk * n_chunks / dt

    return median_with_spread(probe, REPEATS if repeats is None else repeats)


def _time_executor(ex, iters: int) -> float:
    """Single-shot env-steps/s (no repeats) — kept for quick sweeps."""
    return _time_executor_stats(ex, iters, repeats=1)[0]


def throughput(algo: str, n_envs: int, iters: int = 120) -> float:
    env_name, mk = ALGOS[algo]
    env_fn = functools.partial(make_vec, env_name)
    spec, _, _ = env_fn(1)
    agent = mk(spec)
    replay = PrioritizedReplay(ReplayConfig(capacity=50_000, fanout=128),
                               example(spec))
    cfg = loop.LoopConfig(batch_size=64, warmup=64, epsilon=0.1)
    ex = FusedExecutor(agent, replay, env_fn, cfg, n_envs, scan_chunk=20)
    return _time_executor(ex, iters)


def _sharded_executor_throughput(mesh_fn, axis_names, n_cells: int,
                                 compress: bool, n_envs: int,
                                 iters: int):
    """Shared setup for the sharded-throughput workers: DQN/CartPole
    through a ShardedExecutor over ``mesh_fn()`` with one replay shard
    per mesh cell (run inside a process whose forced device count ≥ the
    cell count).  Returns (median env-steps/s, rel_spread)."""
    from repro.core.distributed import (ShardedPrioritizedReplay,
                                        ShardedReplayConfig)
    from repro.runtime.executors import ShardedExecutor

    env_fn = functools.partial(make_vec, "cartpole")
    spec, _, _ = env_fn(1)
    agent = ALGOS["dqn"][1](spec)
    replay = ShardedPrioritizedReplay(
        ShardedReplayConfig(capacity_per_shard=50_000 // n_cells, fanout=128,
                            axis_names=axis_names), example(spec))
    cfg = loop.LoopConfig(batch_size=64, warmup=64, epsilon=0.1)
    ex = ShardedExecutor(agent, replay, env_fn, cfg, n_envs, mesh_fn(),
                         scan_chunk=20, compress_pod_reduce=compress)
    return _time_executor_stats(ex, iters)


def sharded_throughput(n_shards: int, n_envs: int = 16, iters: int = 120):
    """1-D data-axis ShardedExecutor (median env-steps/s, rel_spread)
    at ``n_shards``."""
    from repro.launch.mesh import data_mesh

    return _sharded_executor_throughput(
        lambda: data_mesh(n_shards), ("data",), n_shards, False, n_envs,
        iters)


def run(csv=True):
    rows = []
    for algo in ALGOS:
        base = None
        for n in (1, 2, 4, 8, 16):
            t = throughput(algo, n)
            base = base or t
            rows.append((f"fig10/{algo}_{n}actors", 1e6 / t, t / base))
    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.2f},{derived:.2f}")
    return rows


def pod_sharded_throughput(n_pods: int, n_data: int, compress: bool,
                           n_envs: int = 16, iters: int = 120):
    """Two-axis pod×data ShardedExecutor (median env-steps/s,
    rel_spread), optionally with the int8-EF compressed cross-pod
    reduce."""
    from repro.launch.mesh import pod_data_mesh

    return _sharded_executor_throughput(
        lambda: pod_data_mesh(n_pods, n_data), ("pod", "data"),
        n_pods * n_data, compress, n_envs, iters)


def _run_worker(worker_args, n_devices, n_envs=16, iters=120):
    """Launch this script as a subprocess with the forced device count
    (the XLA flag must be set before jax initializes) and parse the
    STEPS_PER_S= line."""
    script = os.path.abspath(__file__)
    root = os.path.dirname(os.path.dirname(script))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        f"{env.get('XLA_FLAGS', '')} "
        f"--xla_force_host_platform_device_count={n_devices}").strip()
    # src for the repro package, root for benchmarks.* (the worker runs
    # as a script, so its sys.path[0] is benchmarks/, not the repo root)
    src = os.path.join(root, "src")
    paths = f"{src}:{root}"
    env["PYTHONPATH"] = (f"{paths}:{env['PYTHONPATH']}"
                         if env.get("PYTHONPATH") else paths)
    worker_args = worker_args + ["--n-envs", str(n_envs),
                                 "--iters", str(iters)]
    r = subprocess.run([sys.executable, script] + worker_args,
                       capture_output=True, text=True, timeout=1200,
                       env=env, cwd=root)
    out = [line for line in r.stdout.splitlines()
           if line.startswith("STEPS_PER_S=")]
    if not out:
        raise RuntimeError(
            f"worker {worker_args} failed:\n{r.stdout}\n{r.stderr}")
    spreads = [line for line in r.stdout.splitlines()
               if line.startswith("REL_SPREAD=")]
    spread = float(spreads[-1].split("=")[1]) if spreads else 0.0
    return float(out[-1].split("=")[1]), spread


def run_shard_sweep(shard_counts, csv=True):
    """Sweep --xla_force_host_platform_device_count via subprocesses."""
    rows = []
    base = None
    for n in shard_counts:
        t, _ = _run_worker(["--_sharded-worker", str(n)], n)
        base = base or t
        rows.append((f"fig10/sharded_{n}shards", 1e6 / t, t / base))
    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.2f},{derived:.2f}")
    return rows


def shard_pod_points(shard_counts=(1, 2), pod_specs=((2, 1, False),
                                                     (2, 2, False),
                                                     (2, 2, True)),
                     n_envs=16, iters=120):
    """Machine-readable env-steps/s per shard/pod count for
    BENCH_fig10.json: 1-D data-axis counts plus (n_pods, n_data,
    compressed) two-axis points, each in its own forced-device
    subprocess."""
    from benchmarks.timing import REPEATS

    points = []
    for n in shard_counts:
        t, spread = _run_worker(["--_sharded-worker", str(n)], n,
                                n_envs=n_envs, iters=iters)
        points.append({"backend": "sharded", "shards": n, "pods": 1,
                       "compressed": False, "n_envs": n_envs,
                       "env_steps_per_s": round(t, 2),
                       "repeats": REPEATS, "rel_spread": round(spread, 4)})
    for n_pods, n_data, compress in pod_specs:
        t, spread = _run_worker(
            ["--_pod-worker", f"{n_pods},{n_data},{int(compress)}"],
            n_pods * n_data, n_envs=n_envs, iters=iters)
        points.append({"backend": "sharded_pod_data", "shards": n_data,
                       "pods": n_pods, "compressed": bool(compress),
                       "n_envs": n_envs,
                       "env_steps_per_s": round(t, 2),
                       "repeats": REPEATS, "rel_spread": round(spread, 4)})
    return points


# the wall-clock sweep: (n_procs, n_pods, n_data, compress, overlap).
# shards=1 and 2 cover the data axis; the pods=2 pair measures the
# barrier vs the double-buffered overlapped compressed reduce on a real
# 2-process gang.  update_interval=8 (one learn event per iteration at
# 8 envs) is the regime where the overlap pays: the cross-pod
# collective issued at learn i is consumed at learn i+1, so it runs
# concurrently with the next actor chunk; at update_interval=1 the next
# learn in the SAME iteration consumes the carry immediately and there
# is no window (measured in DESIGN.md §10).
WALLCLOCK_SPECS = (
    (1, 1, 1, False, False),
    (1, 1, 2, False, False),
    (2, 1, 2, False, False),
    (2, 2, 1, True, False),
    (2, 2, 1, True, True),
)


def wallclock_points(specs=WALLCLOCK_SPECS, n_envs=8, iters=40,
                     update_interval=8, repeats=3, scan_chunk=20):
    """Real multi-process gang throughput for BENCH_fig10.json: one
    ``launch.multiprocess`` gang per spec, the bench worker reporting
    median-of-``repeats`` env-steps/s with its rel_spread.  All points
    share ``n_envs`` (the global env count splits across mesh cells) so
    they are mutually comparable — and comparable with the emulated
    arms at the same env count, up to the recorded update_interval."""
    from repro.launch import multiprocess as mp

    points = []
    for n_procs, n_pods, n_data, compress, overlap in specs:
        n_cells = n_pods * n_data
        if n_cells % n_procs:
            raise ValueError(f"spec {n_pods}x{n_data} on {n_procs} procs: "
                             "cells must split evenly across the gang")
        worker_args = ["--mode", "bench",
                       "--n-pods", str(n_pods), "--n-data", str(n_data),
                       "--n-envs", str(n_envs), "--iters", str(iters),
                       "--repeats", str(repeats),
                       "--scan-chunk", str(scan_chunk),
                       "--update-interval", str(update_interval)]
        if compress:
            worker_args.append("--compress")
        if overlap:
            worker_args.append("--overlap")
        out = mp.launch(worker_args, n_procs=n_procs,
                        devices_per_proc=n_cells // n_procs)
        kv = mp.parse_kv(out[0])
        points.append({
            "backend": "wallclock", "shards": n_data, "pods": n_pods,
            "compressed": bool(compress), "overlapped": bool(overlap),
            "n_procs": n_procs, "update_interval": update_interval,
            "n_envs": n_envs,
            "env_steps_per_s": round(float(kv["STEPS_PER_S"]), 2),
            "repeats": int(kv.get("REPEATS", repeats)),
            "rel_spread": round(float(kv.get("REL_SPREAD", 0.0)), 4),
        })
    return points


def assert_uniform_n_envs(points):
    """Every point of one emitted BENCH_fig10.json must share its global
    env count: the planner ranks these points against each other, which
    is only a like-for-like comparison when each point runs the same
    workload.  A sweep accidentally mixing env counts (e.g. a wall-clock
    arm defaulting differently from the emulated arms) must fail the
    emit, not silently skew the plan."""
    counts = {p.get("n_envs") for p in points}
    if len(counts) > 1:
        raise ValueError(
            f"BENCH_fig10 points mix n_envs={sorted(counts)}: every point "
            "of one emitted sweep must run the same global env count — "
            "pass one n_envs through all arms (benchmarks/run.py)")
    return points


def realize_plan(plan, iters=120):
    """Measured env-steps/s of a planner-chosen config — in-process when
    the plan needs no mesh, else in a forced-device subprocess (the
    ``--_plan-worker`` mode) so the device count is set before jax
    initializes."""
    if plan.n_devices <= 1:
        from benchmarks.fig9_fanout import plan_throughput
        return plan_throughput(plan, iters=iters)
    spec = (f"{plan.backend},{plan.n_pods},{plan.n_data},"
            f"{plan.publish_interval},{plan.max_staleness},"
            f"{int(plan.compress_pod_reduce)}")
    return _run_worker(["--_plan-worker", spec], plan.n_devices,
                       n_envs=plan.n_envs, iters=iters)[0]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", default="",
                    help="comma-separated shard counts, e.g. 1,2,4 — "
                         "benchmarks the ShardedExecutor per count")
    ap.add_argument("--n-envs", type=int, default=16)
    ap.add_argument("--iters", type=int, default=120)
    ap.add_argument("--_sharded-worker", type=int, default=0,
                    help=argparse.SUPPRESS)
    ap.add_argument("--_pod-worker", default="",
                    help=argparse.SUPPRESS)   # "n_pods,n_data,compress01"
    ap.add_argument("--_plan-worker", default="",
                    help=argparse.SUPPRESS)
    # "backend,n_pods,n_data,publish_interval,max_staleness,compress01"
    args = ap.parse_args()
    if args._sharded_worker:
        t, spread = sharded_throughput(args._sharded_worker,
                                       n_envs=args.n_envs,
                                       iters=args.iters)
        print(f"STEPS_PER_S={t:.2f}")
        print(f"REL_SPREAD={spread:.4f}")
    elif args._pod_worker:
        p, d, c = (int(x) for x in args._pod_worker.split(","))
        t, spread = pod_sharded_throughput(p, d, bool(c), n_envs=args.n_envs,
                                           iters=args.iters)
        print(f"STEPS_PER_S={t:.2f}")
        print(f"REL_SPREAD={spread:.4f}")
    elif args._plan_worker:
        from benchmarks.fig9_fanout import _make_runtime_executor, _steps_per_s
        backend, p, d, pi, ms, c = args._plan_worker.split(",")
        ex = _make_runtime_executor(
            backend, args.n_envs, int(d), int(pi), int(ms),
            pods=int(p) if int(p) > 1 else 0, compress=bool(int(c)))
        print(f"STEPS_PER_S={_steps_per_s(ex, iters=args.iters):.2f}")
    elif args.shards:
        run_shard_sweep([int(x) for x in args.shards.split(",")])
    else:
        run()
