"""Fig. 10 — scalability of DQN/DDPG/SAC vs parallel-actor count.

The paper scales CPU cores; the JAX adaptation scales vectorized actor
lanes (the same resource axis the DSE allocates).  Reports env-steps/s
per algorithm at 1/2/4/8/16 lanes and derived speedup vs 1 lane."""

import time

import jax
import jax.numpy as jnp

from repro.agents.ddpg import DDPGConfig, make_ddpg
from repro.agents.dqn import DQNConfig, make_dqn
from repro.agents.sac import SACConfig, make_sac
from repro.core.replay import PrioritizedReplay, ReplayConfig
from repro.envs.classic import make_vec
from repro.runtime import loop


def example(spec):
    return {
        "obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "action": (jnp.zeros((), jnp.int32) if spec.discrete
                   else jnp.zeros((spec.action_dim,), jnp.float32)),
        "reward": jnp.zeros(()),
        "next_obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "done": jnp.zeros(()),
    }


ALGOS = {
    "dqn": ("cartpole", lambda s: make_dqn(s, DQNConfig())),
    "ddpg": ("pendulum", lambda s: make_ddpg(s, DDPGConfig())),
    "sac": ("pendulum", lambda s: make_sac(s, SACConfig())),
}


def throughput(algo: str, n_envs: int, iters: int = 120) -> float:
    env_name, mk = ALGOS[algo]
    spec, v_reset, v_step = make_vec(env_name, n_envs)
    agent = mk(spec)
    replay = PrioritizedReplay(ReplayConfig(capacity=50_000, fanout=128),
                               example(spec))
    cfg = loop.LoopConfig(batch_size=64, warmup=64, epsilon=0.1)
    step = loop.make_parallel_step(agent, replay, v_step, cfg, n_envs)
    st = loop.init_loop_state(agent, replay, v_reset, jax.random.PRNGKey(0),
                              n_envs)

    @jax.jit
    def chunk(st):
        def body(s, _):
            s, _m = step(s)
            return s, None
        s, _ = jax.lax.scan(body, st, None, length=20)
        return s

    st = chunk(st)
    jax.block_until_ready(st.obs)
    t0 = time.perf_counter()
    for _ in range(iters // 20):
        st = chunk(st)
    jax.block_until_ready(st.obs)
    return n_envs * 20 * (iters // 20) / (time.perf_counter() - t0)


def run(csv=True):
    rows = []
    for algo in ALGOS:
        base = None
        for n in (1, 2, 4, 8, 16):
            t = throughput(algo, n)
            base = base or t
            rows.append((f"fig10/{algo}_{n}actors", 1e6 / t, t / base))
    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.2f},{derived:.2f}")
    return rows


if __name__ == "__main__":
    run()
