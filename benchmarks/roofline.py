"""§Roofline — aggregate the dry-run artifacts into the per-cell table.

Reads experiments/dryrun/*.json (written by launch/dryrun.py) and prints
the three-term roofline per (arch × shape × mesh): seconds per term,
dominant bottleneck, MODEL_FLOPS/HLO_FLOPs useful ratio, and the
roofline fraction t_compute/max(terms) — the headline §Perf number."""

import glob
import json
import os
from typing import Dict, List

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def load_records(d: str = DRYRUN_DIR) -> List[Dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def fraction(r: Dict) -> float:
    mx = max(r.get("t_compute", 0), r.get("t_memory", 0),
             r.get("t_collective", 0), 1e-30)
    return r.get("t_compute", 0) / mx


def table(recs: List[Dict]) -> str:
    hdr = (f"{'arch':<26}{'shape':<13}{'mesh':<6}{'t_comp':>9}{'t_mem':>9}"
           f"{'t_coll':>9}{'dom':>8}{'useful':>8}{'frac':>7}")
    lines = [hdr, "-" * len(hdr)]
    for r in recs:
        if r.get("status") == "skipped":
            lines.append(f"{r['arch']:<26}{r['shape']:<13}"
                         f"{'x'.join(map(str, r['mesh'])):<6}"
                         f"{'— skipped (full attention @500k)':>40}")
            continue
        if r.get("status") != "ok":
            lines.append(f"{r['arch']:<26}{r['shape']:<13}"
                         f"{'x'.join(map(str, r['mesh'])):<6}  ERROR: "
                         f"{r.get('error', '')[:60]}")
            continue
        lines.append(
            f"{r['arch']:<26}{r['shape']:<13}"
            f"{'x'.join(map(str, r['mesh'])):<6}"
            f"{r['t_compute']:>9.3f}{r['t_memory']:>9.3f}"
            f"{r['t_collective']:>9.3f}{r['dominant']:>8}"
            f"{(r.get('useful_flops_ratio') or 0):>8.3f}{fraction(r):>7.3f}")
    return "\n".join(lines)


def run(csv=True):
    rows = []
    for prefix, d in (("roofline", DRYRUN_DIR),
                      ("roofline_opt", DRYRUN_DIR + "_opt")):
        if not os.path.isdir(d):
            continue
        for r in load_records(d):
            if r.get("status") != "ok":
                continue
            tag = (f"{prefix}/{r['arch']}_{r['shape']}"
                   f"_pod{2 if r['multi_pod'] else 1}")
            step_s = max(r["t_compute"], r["t_memory"], r["t_collective"])
            rows.append((tag, step_s * 1e6, fraction(r)))
    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.1f},{derived:.4f}")
    return rows


if __name__ == "__main__":
    print(table(load_records()))
