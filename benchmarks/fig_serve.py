"""Replay-service throughput: sustained insert/sample rates vs writer
count (DESIGN.md §11 — the service-shape inputs of the runtime planner).

The decoupled runtime's capacity question is not "how fast is one
transaction" (benchmarks/replay_micro.py answers that) but "what insert
rate can the *service* sustain for N concurrent writers while the rate
limiter holds the sample ratio" — the quantity
``planner.select_replay_service`` needs to size ``n_replay_shards`` for
a measured executor.  Each point drives an in-process ``ReplayService``
(the same shard ops and lock discipline the TCP server dispatches into;
the wire itself is exercised by the replay-service-smoke CI gang) with
N writer threads appending rollout-sized chunks against one greedy
sampler thread, under the loose gang-band ``RateLimiter`` — so the two
reported rates are *coupled* by flow control exactly as in production:

    samples_per_s ≈ spi · inserts_per_s        (realized_spi recorded)

Metric: ``inserts_per_s`` (primary, gated by benchmarks/compare.py) with
``samples_per_s``/``realized_spi`` as measurement-side companions;
median-of-N with recorded dispersion (benchmarks/timing.py).
``--emit-json DIR`` writes ``BENCH_serve.json`` (figure "serve",
benchmarks/schema.py); the committed repo-root baseline rides the same
perf gate as the other figures.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import threading
import time

import numpy as np

from benchmarks.timing import REPEATS

SERVE_JSON = "BENCH_serve.json"

OBS_DIM = 4           # cartpole-shaped transition payload


def _example():
    import jax.numpy as jnp

    return {
        "obs": jnp.zeros((OBS_DIM,), jnp.float32),
        "action": jnp.zeros((), jnp.int32),
        "reward": jnp.zeros(()),
        "next_obs": jnp.zeros((OBS_DIM,), jnp.float32),
        "done": jnp.zeros(()),
    }


def _items(n: int, seed: int):
    rng = np.random.RandomState(seed)
    return {
        "obs": rng.randn(n, OBS_DIM).astype(np.float32),
        "action": rng.randint(0, 2, size=(n,)).astype(np.int32),
        "reward": rng.randn(n).astype(np.float32),
        "next_obs": rng.randn(n, OBS_DIM).astype(np.float32),
        "done": np.zeros((n,), np.float32),
    }


def _build_service(n_shards: int, writers: int, spi: float, batch: int,
                   insert_chunk: int, capacity_per_shard: int):
    from repro.service import (RateLimiter, ReplayService,
                               ReplayServiceConfig)

    limiter = RateLimiter(
        samples_per_insert=spi,
        min_size_to_sample=batch,
        # the loose gang band: absorb every writer landing a full chunk
        # inside one admission window (launch/multiprocess.py sizes the
        # real gang's server identically)
        error_buffer=2.0 * max(float(batch), spi * insert_chunk * writers))
    service = ReplayService(
        ReplayServiceConfig(capacity_per_shard=capacity_per_shard,
                            n_shards=n_shards, fanout=128,
                            router="round_robin"),
        _example(), rate_limiter=limiter)
    return service, limiter


def _drive(service, limiter, writers: int, chunks_per_writer: int,
           insert_chunk: int, batch: int) -> float:
    """One measured run: N writer threads push their chunk budget through
    rate-limited appends while a greedy sampler drains sample+priority
    round trips; returns the wall time start→drained."""
    done = threading.Event()
    errors = []

    def writer(wid: int):
        try:
            for c in range(chunks_per_writer):
                service.append(f"w{wid}", _items(insert_chunk, wid * 7919 + c),
                               timeout=120.0)
        except Exception as e:  # noqa: BLE001 — surface on the main thread
            errors.append(e)
            done.set()

    def sampler():
        while True:
            try:
                out = service.sample(batch, timeout=0.25)
            except TimeoutError:
                if done.is_set():
                    return
                continue
            except Exception as e:  # noqa: BLE001
                errors.append(e)
                return
            service.update_priorities(out["sample_id"],
                                      np.ones((batch,), np.float32))

    threads = [threading.Thread(target=writer, args=(w,))
               for w in range(writers)]
    st = threading.Thread(target=sampler)
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    st.start()
    for t in threads:
        t.join()
    done.set()
    st.join()
    dt = time.perf_counter() - t0
    if errors:
        raise errors[0]
    return dt


def serve_points(writer_counts=(1, 2, 4), shard_counts=(1, 2),
                 spi: float = 8.0, batch: int = 64, insert_chunk: int = 64,
                 chunks_per_writer: int = 16, repeats: int = REPEATS):
    """The committed sweep: (writers × shards) sustained-rate points.
    Each (writers, shards) cell builds one service, warms the jitted
    shard ops with a throwaway run, then measures ``repeats`` runs and
    keeps the median-``inserts_per_s`` run's coupled numbers."""
    points = []
    for n_shards in shard_counts:
        if batch % n_shards:
            continue
        for writers in writer_counts:
            service, limiter = _build_service(
                n_shards, writers, spi, batch, insert_chunk,
                capacity_per_shard=max(4096, (writers * chunks_per_writer
                                              * insert_chunk * (repeats + 2))
                                       // n_shards))
            # warmup: compile append/sample/update for every shard shape
            _drive(service, limiter, writers, 2, insert_chunk, batch)
            runs = []
            for _ in range(max(1, repeats)):
                i0, s0 = limiter.inserts, limiter.samples
                dt = _drive(service, limiter, writers, chunks_per_writer,
                            insert_chunk, batch)
                runs.append(((limiter.inserts - i0) / dt,
                             (limiter.samples - s0) / dt))
            runs.sort()
            ins_rates = [r[0] for r in runs]
            med_i, med_s = runs[len(runs) // 2]
            spread = ((max(ins_rates) - min(ins_rates)) / med_i
                      if med_i > 0 else 0.0)
            points.append({
                "writers": writers,
                "n_shards": n_shards,
                "spi": spi,
                "batch_size": batch,
                "inserts_per_s": round(med_i, 2),
                "samples_per_s": round(med_s, 2),
                "realized_spi": round(
                    limiter.realized_samples_per_insert(), 4),
                "repeats": max(1, repeats),
                "rel_spread": round(spread, 4),
            })
    return points


def emit_json(out_dir: str, smoke: bool = False) -> str:
    kwargs = (dict(writer_counts=(1, 2), shard_counts=(1, 2),
                   chunks_per_writer=8) if smoke else {})
    payload = {
        "figure": "serve",
        "metric": "inserts_per_s",
        "smoke": smoke,
        "points": serve_points(**kwargs),
    }
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, SERVE_JSON)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"# wrote {path} ({len(payload['points'])} points)",
          file=sys.stderr)
    return path


def run(csv=True):
    """CSV mode for the benchmarks.run harness."""
    rows = []
    for p in serve_points(writer_counts=(1, 2), shard_counts=(1,),
                          chunks_per_writer=4, repeats=1):
        name = f"serve/w{p['writers']}_s{p['n_shards']}"
        rows.append((name, 1e6 / p["inserts_per_s"], p["inserts_per_s"]))
    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.3f},{derived:.2f}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--emit-json", default=None, metavar="DIR")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized sweep, same schema and code paths")
    args = ap.parse_args()
    if args.emit_json:
        emit_json(args.emit_json, smoke=args.smoke)
    else:
        run(csv=True)
