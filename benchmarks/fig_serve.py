"""Replay-service throughput: sustained insert/sample rates vs writer
count (DESIGN.md §11 — the service-shape inputs of the runtime planner).

The decoupled runtime's capacity question is not "how fast is one
transaction" (benchmarks/replay_micro.py answers that) but "what insert
rate can the *service* sustain for N concurrent writers while the rate
limiter holds the sample ratio" — the quantity
``planner.select_replay_service`` needs to size ``n_replay_shards`` for
a measured executor.  Each point drives an in-process ``ReplayService``
(the same shard ops and lock discipline the TCP server dispatches into;
the wire itself is exercised by the replay-service-smoke CI gang) with
N writer threads appending rollout-sized chunks against one greedy
sampler thread, under the loose gang-band ``RateLimiter`` — so the two
reported rates are *coupled* by flow control exactly as in production:

    samples_per_s ≈ spi · inserts_per_s        (realized_spi recorded)

Metric: ``inserts_per_s`` (primary, gated by benchmarks/compare.py) with
``samples_per_s``/``realized_spi`` as measurement-side companions;
median-of-N with recorded dispersion (benchmarks/timing.py).
``--emit-json DIR`` writes ``BENCH_serve.json`` (figure "serve",
benchmarks/schema.py); the committed repo-root baseline rides the same
perf gate as the other figures.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import threading
import time

import numpy as np

from benchmarks.timing import REPEATS

SERVE_JSON = "BENCH_serve.json"

OBS_DIM = 4           # cartpole-shaped transition payload


def _example():
    import jax.numpy as jnp

    return {
        "obs": jnp.zeros((OBS_DIM,), jnp.float32),
        "action": jnp.zeros((), jnp.int32),
        "reward": jnp.zeros(()),
        "next_obs": jnp.zeros((OBS_DIM,), jnp.float32),
        "done": jnp.zeros(()),
    }


def _items(n: int, seed: int):
    rng = np.random.RandomState(seed)
    return {
        "obs": rng.randn(n, OBS_DIM).astype(np.float32),
        "action": rng.randint(0, 2, size=(n,)).astype(np.int32),
        "reward": rng.randn(n).astype(np.float32),
        "next_obs": rng.randn(n, OBS_DIM).astype(np.float32),
        "done": np.zeros((n,), np.float32),
    }


def _build_service(n_shards: int, writers: int, spi: float, batch: int,
                   insert_chunk: int, capacity_per_shard: int):
    from repro.service import (RateLimiter, ReplayService,
                               ReplayServiceConfig)

    limiter = RateLimiter(
        samples_per_insert=spi,
        min_size_to_sample=batch,
        # the loose gang band: absorb every writer landing a full chunk
        # inside one admission window (launch/multiprocess.py sizes the
        # real gang's server identically)
        error_buffer=2.0 * max(float(batch), spi * insert_chunk * writers))
    service = ReplayService(
        ReplayServiceConfig(capacity_per_shard=capacity_per_shard,
                            n_shards=n_shards, fanout=128,
                            router="round_robin"),
        _example(), rate_limiter=limiter)
    return service, limiter


def _drive(service, limiter, writers: int, chunks_per_writer: int,
           insert_chunk: int, batch: int) -> float:
    """One measured run: N writer threads push their chunk budget through
    rate-limited appends while a greedy sampler drains sample+priority
    round trips; returns the wall time start→drained."""
    done = threading.Event()
    errors = []

    def writer(wid: int):
        try:
            for c in range(chunks_per_writer):
                service.append(f"w{wid}", _items(insert_chunk, wid * 7919 + c),
                               timeout=120.0)
        except Exception as e:  # noqa: BLE001 — surface on the main thread
            errors.append(e)
            done.set()

    def sampler():
        while True:
            try:
                out = service.sample(batch, timeout=0.25)
            except TimeoutError:
                if done.is_set():
                    return
                continue
            except Exception as e:  # noqa: BLE001
                errors.append(e)
                return
            service.update_priorities(out["sample_id"],
                                      np.ones((batch,), np.float32))

    threads = [threading.Thread(target=writer, args=(w,))
               for w in range(writers)]
    st = threading.Thread(target=sampler)
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    st.start()
    for t in threads:
        t.join()
    done.set()
    st.join()
    dt = time.perf_counter() - t0
    if errors:
        raise errors[0]
    return dt


def serve_points(writer_counts=(1, 2, 4), shard_counts=(1, 2),
                 spi: float = 8.0, batch: int = 64, insert_chunk: int = 64,
                 chunks_per_writer: int = 16, repeats: int = REPEATS):
    """The committed sweep: (writers × shards) sustained-rate points.
    Each (writers, shards) cell builds one service, warms the jitted
    shard ops with a throwaway run, then measures ``repeats`` runs and
    keeps the median-``inserts_per_s`` run's coupled numbers."""
    points = []
    for n_shards in shard_counts:
        if batch % n_shards:
            continue
        for writers in writer_counts:
            service, limiter = _build_service(
                n_shards, writers, spi, batch, insert_chunk,
                capacity_per_shard=max(4096, (writers * chunks_per_writer
                                              * insert_chunk * (repeats + 2))
                                       // n_shards))
            # warmup: compile append/sample/update for every shard shape
            _drive(service, limiter, writers, 2, insert_chunk, batch)
            runs = []
            for _ in range(max(1, repeats)):
                i0, s0 = limiter.inserts, limiter.samples
                dt = _drive(service, limiter, writers, chunks_per_writer,
                            insert_chunk, batch)
                runs.append(((limiter.inserts - i0) / dt,
                             (limiter.samples - s0) / dt))
            runs.sort()
            ins_rates = [r[0] for r in runs]
            med_i, med_s = runs[len(runs) // 2]
            spread = ((max(ins_rates) - min(ins_rates)) / med_i
                      if med_i > 0 else 0.0)
            points.append({
                "writers": writers,
                "n_shards": n_shards,
                "spi": spi,
                "batch_size": batch,
                "inserts_per_s": round(med_i, 2),
                "samples_per_s": round(med_s, 2),
                "realized_spi": round(
                    limiter.realized_samples_per_insert(), 4),
                "repeats": max(1, repeats),
                "rel_spread": round(spread, 4),
            })
    return points


def fault_points(writers: int = 2, n_shards: int = 1, spi: float = 8.0,
                 batch: int = 64, insert_chunk: int = 64,
                 chunks_per_writer: int = 24, outage_s: float = 0.5):
    """The recovery arm (DESIGN.md §14): the same coupled writer/sampler
    load as ``serve_points``, but over real TCP clients against a served
    instance that is crashed (soft ``FaultPlan`` — identical wire
    semantics to a process kill, without the multi-second reimport) at
    its midpoint append, held down for ``outage_s``, then restored from
    its per-append shard snapshots onto the same port.  The measured
    quantity is ``recovery_s`` — wall seconds from the kill to the first
    re-admitted append ack — with the (outage-inclusive) sustained rates
    alongside.  Exactly-once is asserted, not assumed: the run fails
    unless every chunk landed exactly once across the restart."""
    import shutil
    import tempfile

    from repro.checkpoint.manager import CheckpointManager
    from repro.service import (FaultPlan, ReplayClient, RetryPolicy, serve,
                               wait_for_service)

    total_appends = writers * chunks_per_writer
    crash_at = max(2, total_appends // 2)
    capacity = max(4096, (total_appends * insert_chunk) // n_shards + batch)
    snap_dir = tempfile.mkdtemp(prefix="fig_serve_snap_")
    service, limiter = _build_service(n_shards, writers, spi, batch,
                                      insert_chunk, capacity)
    service.attach_snapshots(CheckpointManager(snap_dir, keep=2),
                             every_appends=1)
    server, port = serve(service, fault_plan=FaultPlan(
        crash_on_op=f"append:{crash_at}", hard=False))
    wait_for_service("127.0.0.1", port, timeout=30.0)

    holders = {"service": service, "server": server}
    marks = {"t_kill": None, "t_recover": None}
    mark_lock = threading.Lock()
    done = threading.Event()
    errors = []
    retry_kw = dict(base=0.02, cap=0.25, jitter=0.25, deadline=120.0)

    def monitor():
        """Waits for the injected crash, holds the planned outage, then
        restores a fresh service from the snapshot lineage on the same
        port (the in-process twin of the gang drill's server respawn)."""
        try:
            while not holders["server"].crashed.is_set():
                if done.is_set():
                    return
                time.sleep(0.02)
            with mark_lock:
                marks["t_kill"] = time.perf_counter()
            time.sleep(outage_s)  # deliberate downtime before restart
            svc2, _ = _build_service(n_shards, writers, spi, batch,
                                     insert_chunk, capacity)
            manager = CheckpointManager(snap_dir, keep=2)
            if svc2.restore_snapshot(manager) is None:
                raise RuntimeError("no snapshot to restore from")
            svc2.attach_snapshots(manager, every_appends=1)
            s2, _ = serve(svc2, port=port)
            holders["service"], holders["server"] = svc2, s2
        except Exception as e:  # noqa: BLE001 — surface on the main thread
            errors.append(e)
            done.set()

    def writer(wid: int):
        try:
            client = ReplayClient(
                "127.0.0.1", port, timeout=30.0,
                retry=RetryPolicy(seed=wid, **retry_kw))
            for c in range(chunks_per_writer):
                client.append(f"w{wid}", _items(insert_chunk, wid * 7919 + c),
                              timeout=60.0)
                now = time.perf_counter()
                # only an ack on a *reconnected* client marks recovery —
                # an in-flight reply the dying server flushes right
                # after ``crashed`` is set must not count
                if client.reconnects:
                    with mark_lock:
                        if (marks["t_kill"] is not None
                                and marks["t_recover"] is None):
                            marks["t_recover"] = now
            client.close()
        except Exception as e:  # noqa: BLE001
            errors.append(e)
            done.set()

    def sampler():
        client = ReplayClient("127.0.0.1", port, timeout=30.0,
                              retry=RetryPolicy(seed=999, **retry_kw))
        while not done.is_set():
            try:
                out = client.sample(batch, timeout=0.25)
            except RuntimeError as e:
                if "TimeoutError" in str(e):
                    continue  # quiet limiter, not an outage
                errors.append(e)
                return
            except ConnectionError:
                continue  # outage: the retry deadline outlives it
            if out.get("stopped"):
                break
            try:
                client.update_priorities(out["sample_id"],
                                         np.ones((batch,), np.float32))
            except (RuntimeError, ConnectionError):
                pass  # handle aged out across the crash — stale is fine
        client.close()

    mon = threading.Thread(target=monitor, daemon=True)
    ws = [threading.Thread(target=writer, args=(w,)) for w in range(writers)]
    st = threading.Thread(target=sampler, daemon=True)
    t0 = time.perf_counter()
    mon.start()
    for t in ws:
        t.start()
    st.start()
    for t in ws:
        t.join()
    dt = time.perf_counter() - t0
    done.set()
    st.join(timeout=30.0)
    mon.join(timeout=30.0)
    if errors:
        raise errors[0]
    if marks["t_kill"] is None or marks["t_recover"] is None:
        raise RuntimeError(
            f"fault arm never crossed the crash (kill={marks['t_kill']}, "
            f"recover={marks['t_recover']}) — crash_at={crash_at} vs "
            f"{total_appends} appends")

    final = holders["service"]
    stats = final.stats()
    expected = total_appends * insert_chunk
    if stats["inserts"] != expected:
        raise RuntimeError(
            f"exactly-once violated across restart: {stats['inserts']} "
            f"inserts != {total_appends} appends × {insert_chunk} "
            f"(dup_appends={stats['dup_appends']}, "
            f"writer_appends={stats['writer_appends']})")
    final.stop()
    holders["server"].shutdown()
    holders["server"].server_close()
    shutil.rmtree(snap_dir, ignore_errors=True)

    return [{
        "writers": writers,
        "n_shards": n_shards,
        "spi": spi,
        "batch_size": batch,
        "fault": True,
        "outage_s": outage_s,
        "inserts_per_s": round(stats["inserts"] / dt, 2),
        "samples_per_s": round(stats["samples"] / dt, 2),
        "realized_spi": round(stats["samples"] / max(1, stats["inserts"]), 4),
        "recovery_s": round(marks["t_recover"] - marks["t_kill"], 3),
    }]


def emit_json(out_dir: str, smoke: bool = False) -> str:
    kwargs = (dict(writer_counts=(1, 2), shard_counts=(1, 2),
                   chunks_per_writer=8) if smoke else {})
    # the fault arm runs full-size even under --smoke: its rate carries
    # a fixed outage+restore cost, so a shorter run would de-calibrate
    # the point against the committed baseline the gate matches it to
    payload = {
        "figure": "serve",
        "metric": "inserts_per_s",
        "smoke": smoke,
        "points": serve_points(**kwargs) + fault_points(),
    }
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, SERVE_JSON)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"# wrote {path} ({len(payload['points'])} points)",
          file=sys.stderr)
    return path


def run(csv=True):
    """CSV mode for the benchmarks.run harness."""
    rows = []
    for p in serve_points(writer_counts=(1, 2), shard_counts=(1,),
                          chunks_per_writer=4, repeats=1):
        name = f"serve/w{p['writers']}_s{p['n_shards']}"
        rows.append((name, 1e6 / p["inserts_per_s"], p["inserts_per_s"]))
    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.3f},{derived:.2f}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--emit-json", default=None, metavar="DIR")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized sweep, same schema and code paths")
    ap.add_argument("--fault", action="store_true",
                    help="run only the crash-and-restore recovery arm "
                         "and print its point (emit-json always "
                         "includes it)")
    args = ap.parse_args()
    if args.fault and not args.emit_json:
        print(json.dumps(fault_points(), indent=2))
    elif args.emit_json:
        emit_json(args.emit_json, smoke=args.smoke)
    else:
        run(csv=True)
