"""Actor-serve load generator: latency p50/p99 + sustained requests/s
for the continuous-batching inference frontend (DESIGN.md §13).

The training sweeps measure the learn loop; this measures the traffic
surface — N simulated users submitting token prompts at a target
request rate against a live ``ActorServer``.  Each measured run also
performs the production param drill: at 40% completion a new parameter
version is published through the REPLAY SERVICE's versioned params
channel (service/server.py ``put_params`` — the same publisher a
training learner uses), and the point records p99 latency before and
after the hot swap, so the §13 no-latency-spike contract is a measured
number, not a claim.

Cells are (users × target_rps) with one deliberate **overload** cell
(target far above capacity): sub-capacity cells answer "can the server
hold the rate" (the CI ``--check`` floor), the overload cell measures
raw serving capacity — the number the >30% compare gate bites on.

Metric: ``requests_per_s`` (primary, gated) with p50/p99 and the swap
drill's p99 split as measurement-side companions; median-of-N with
recorded dispersion (benchmarks/timing.py).  ``--emit-json DIR`` writes
``BENCH_actor.json`` (figure "actor", benchmarks/schema.py); the
committed repo-root baseline rides the same perf gate as the other
figures.  ``--check`` makes the smoke run self-asserting for CI:
sustained floor on sub-capacity cells + an observed version advance.
"""

from __future__ import annotations

import argparse
import json
import os
import pickle
import sys
import threading
import time

import numpy as np

from benchmarks.timing import REPEATS

ACTOR_JSON = "BENCH_actor.json"

ARCH = "granite_8b"        # dense smoke config — the servable family
SLOTS = 4
GEN_TOKENS = 8
BUCKETS = (4, 8)
MAX_LEN = BUCKETS[-1] + GEN_TOKENS
PUBLISH_AT = 0.4           # fraction of completions before the param swap
SUSTAIN_FLOOR = 0.6        # --check: sub-capacity cells must hold this

# (users, target_rps, overload) sweep cells
FULL_CELLS = ((1, 2.0, False), (2, 2.0, False), (4, 4.0, False),
              (2, 16.0, True))
SMOKE_CELLS = ((1, 2.0, False), (2, 2.0, False), (2, 16.0, True))


def build_server():
    """One warm server + its replay-service param publisher."""
    import jax

    from repro.configs import get_config
    from repro.models import backbone
    from repro.serve import ActorServeConfig, ActorServer
    from repro.service import ReplayService, ReplayServiceConfig

    cfg = get_config(ARCH, smoke=True)
    params = backbone.init_params(cfg, jax.random.PRNGKey(0))
    # the publisher: the same versioned channel a training learner's
    # put_params rides (service/server.py); replay shards are unused here
    service = ReplayService(
        ReplayServiceConfig(capacity_per_shard=8, n_shards=1),
        {"obs": np.zeros((2,), np.float32)})
    # version 0 aligns the buffer with the service channel's counter
    # (put_params publishes version 1, 2, ... — the poll floor must
    # start below the first publish)
    server = ActorServer(
        cfg, params,
        ActorServeConfig(slots=SLOTS, max_len=MAX_LEN, buckets=BUCKETS,
                         max_new_tokens=GEN_TOKENS),
        params_version=0, param_source=service)
    blob = pickle.dumps(jax.tree.map(np.asarray, params),
                        protocol=pickle.HIGHEST_PROTOCOL)
    return cfg, server, service, blob


def load_run(cfg, server, service, blob, *, users: int, n_requests: int,
             target_rps: float, seed: int) -> dict:
    """One measured run: open-loop Poisson arrivals split across
    ``users`` submitter threads, one mid-run param publication through
    the service channel, client-side latency collection."""
    rng = np.random.RandomState(seed)
    per_user = n_requests // users
    n_total = per_user * users
    prompts = [rng.randint(0, cfg.vocab_size, size=int(n))
               for n in rng.randint(1, BUCKETS[-1] + 1, size=n_total)]
    # deterministic open-loop spacing at exactly the target rate: the
    # measured dispersion then reflects the SERVER, not arrival noise
    # (Poisson gaps at n≈12 made rel_spread arrival-dominated, which
    # would widen the compare gate's tolerance to uselessness)
    gap = users / target_rps
    gaps = np.full((users, per_user), gap)
    gaps[:, 0] = gap * (np.arange(users) + 1) / users  # stagger users

    handles = [[None] * per_user for _ in range(users)]
    submitted = threading.Barrier(users + 1)

    def user(u: int):
        submitted.wait()
        for i in range(per_user):
            time.sleep(gaps[u][i])
            handles[u][i] = server.submit(prompts[u * per_user + i])

    threads = [threading.Thread(target=user, args=(u,)) for u in range(users)]
    for t in threads:
        t.start()
    v0 = server.params.version
    submitted.wait()
    t0 = time.perf_counter()

    # the swap drill: publish once PUBLISH_AT of the requests completed
    flat = lambda: [h for row in handles for h in row if h is not None]  # noqa: E731
    swap_t = None
    while True:
        done = sum(h.done() for h in flat())
        if done >= max(1, int(PUBLISH_AT * n_total)):
            service.put_params(blob)
            swap_t = time.perf_counter()
            break
        if done >= n_total:
            break
        time.sleep(0.005)

    for t in threads:
        t.join()
    completions = [h.result(timeout=300.0) for h in flat()]
    t_end = max(c.finished_at for c in completions)
    lat_ms = np.asarray([c.latency_s for c in completions]) * 1e3
    record = {
        "requests_per_s": n_total / (t_end - t0),
        "p50_ms": float(np.percentile(lat_ms, 50)),
        "p99_ms": float(np.percentile(lat_ms, 99)),
        "param_swaps": int(server.params.version - v0),
    }
    if swap_t is not None:
        before = [c.latency_s * 1e3 for c in completions
                  if c.finished_at < swap_t]
        after = [c.latency_s * 1e3 for c in completions
                 if c.finished_at >= swap_t]
        if before:
            record["p99_before_swap_ms"] = float(np.percentile(before, 99))
        if after:
            record["p99_after_swap_ms"] = float(np.percentile(after, 99))
    return record


def actor_points(cells=FULL_CELLS, n_requests: int = 12,
                 repeats: int = REPEATS, verbose: bool = False):
    """The committed sweep: one warm server serves every cell; each cell
    is median-of-``repeats`` runs keyed on sustained requests/s."""
    cfg, server, service, blob = build_server()
    server.start()
    try:
        # warm both prefill buckets + the decode program out of the
        # measurement window
        warm = [server.submit(np.arange(1 + (BUCKETS[-1] - 1) * i,
                                        dtype=np.int32) % cfg.vocab_size)
                for i in (0, 1)]
        for h in warm:
            h.result(timeout=300.0)
        points = []
        for users, target_rps, overload in cells:
            runs = []
            for r in range(max(1, repeats)):
                runs.append(load_run(
                    cfg, server, service, blob, users=users,
                    n_requests=n_requests, target_rps=target_rps,
                    seed=1000 * users + r))
            runs.sort(key=lambda rec: rec["requests_per_s"])
            med = runs[len(runs) // 2]
            rates = [rec["requests_per_s"] for rec in runs]
            spread = ((max(rates) - min(rates)) / med["requests_per_s"]
                      if med["requests_per_s"] > 0 else 0.0)
            point = {
                "users": users,
                "target_rps": target_rps,
                "overload": overload,
                "slots": SLOTS,
                "gen_tokens": GEN_TOKENS,
                "arch": cfg.name,
                "prompt_buckets": "/".join(str(b) for b in BUCKETS),
                "repeats": max(1, repeats),
                "rel_spread": round(spread, 4),
                **{k: (round(v, 2) if isinstance(v, float) else v)
                   for k, v in med.items()},
            }
            points.append(point)
            if verbose:
                print(f"# users={users} rate={target_rps} "
                      f"overload={overload}: "
                      f"{point['requests_per_s']} req/s, "
                      f"p99 {point['p99_ms']} ms, "
                      f"swaps {point['param_swaps']}", file=sys.stderr)
        return points, server.stats()
    finally:
        server.stop()
        service.stop()


def check_points(points, stats) -> int:
    """CI self-check: sub-capacity cells hold the target rate; the
    mid-run publication was observed (version counter advanced) with
    the p99 split recorded.  Returns the number of failures."""
    failures = 0
    for p in points:
        label = f"users={p['users']} rate={p['target_rps']}"
        if not p["overload"]:
            floor = SUSTAIN_FLOOR * p["target_rps"]
            ok = p["requests_per_s"] >= floor
            print(f"{'PASS' if ok else 'FAIL'} {label}: sustained "
                  f"{p['requests_per_s']} req/s (floor {floor:.2f})")
            failures += not ok
        swapped = p.get("param_swaps", 0) >= 1
        recorded = "p99_before_swap_ms" in p
        print(f"{'PASS' if swapped and recorded else 'FAIL'} {label}: "
              f"param swap observed={swapped} "
              f"p99 before/after = {p.get('p99_before_swap_ms')}"
              f"/{p.get('p99_after_swap_ms')} ms")
        failures += not (swapped and recorded)
    print(f"PARAM_VERSION={stats['params_version']} "
          f"SWAPS={stats['param_swaps']}")
    return failures


def emit_json(out_dir: str, smoke: bool = False, check: bool = False) -> str:
    points, stats = actor_points(
        cells=SMOKE_CELLS if smoke else FULL_CELLS, verbose=True)
    payload = {
        "figure": "actor",
        "metric": "requests_per_s",
        "smoke": smoke,
        "points": points,
    }
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, ACTOR_JSON)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"# wrote {path} ({len(points)} points)", file=sys.stderr)
    if check and check_points(points, stats):
        raise SystemExit("actor-serve check failed")
    return path


def run(csv=True):
    """CSV mode for the benchmarks.run harness."""
    points, _ = actor_points(cells=SMOKE_CELLS, n_requests=8, repeats=1)
    rows = [(f"actor/u{p['users']}_r{p['target_rps']}"
             + ("_overload" if p["overload"] else ""),
             1e6 / p["requests_per_s"], p["requests_per_s"])
            for p in points]
    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.3f},{derived:.2f}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--emit-json", default=None, metavar="DIR")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized sweep, same schema and code paths")
    ap.add_argument("--check", action="store_true",
                    help="fail unless sub-capacity cells sustain the "
                         "target and the mid-run param swap is observed")
    args = ap.parse_args()
    if args.emit_json:
        emit_json(args.emit_json, smoke=args.smoke, check=args.check)
    else:
        run(csv=True)
