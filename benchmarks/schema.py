"""Schema for the machine-readable BENCH json (the perf trajectory CI
gates on).

One place defines what ``benchmarks/run.py --emit-json`` may write and
what ``benchmarks/compare.py`` and ``runtime/planner.py`` may assume:
every payload carries ``figure``/``metric`` (``FIGURE_METRICS`` names
the one measured rate per figure), and the executor sweeps (fig9/fig10)
share the ``env_steps_per_s`` unit — the invariant that makes
cross-file candidate scoring in the planner legal.  The replay
microbenchmark payload carries its own unit (``replay_ops_per_s``) and
is never scored against the executor sweeps.  Points may carry the
median-of-N dispersion record (``repeats``/``rel_spread``,
benchmarks/timing.py).

Dependency-free on purpose (no jsonschema): CI validates the artifacts
with the same stdlib-only code the planner imports.

    PYTHONPATH=src python -m benchmarks.schema out/BENCH_fig9.json ...

exits non-zero on the first invalid file.
"""

from __future__ import annotations

import json
import sys
from typing import Any, Dict, List

# field name → (type(s), required) per point, keyed by payload "figure".
# bool is checked before int (bool is an int subclass in Python).
# Every point may carry the median-of-N dispersion record
# (benchmarks/timing.py): repeats + rel_spread.
_COMMON_POINT = {
    "n_envs": (int, False),
    "repeats": (int, False),
    "rel_spread": ((int, float), False),
}

# the one measured rate per figure — compare.py reads the payload's
# "metric" to find it, so every figure's unit stays self-describing
FIGURE_METRICS: Dict[str, str] = {
    "fig9": "env_steps_per_s",
    "fig10": "env_steps_per_s",
    "replay": "replay_ops_per_s",
    "serve": "inserts_per_s",
    "actor": "requests_per_s",
}

POINT_FIELDS: Dict[str, Dict[str, tuple]] = {
    "fig9": {
        **_COMMON_POINT,
        "env_steps_per_s": ((int, float), True),
        "backend": (str, True),
        "shards": (int, True),
        "pods": (int, True),
        "publish_interval": (int, True),
        "max_staleness": (int, True),
        "speedup_vs_sync": ((int, float), False),
    },
    "fig10": {
        **_COMMON_POINT,
        "env_steps_per_s": ((int, float), True),
        "backend": (str, True),
        "shards": (int, True),
        "pods": (int, True),
        "compressed": (bool, True),
        # wall-clock arm (backend="wallclock"): real multi-process gang
        # points from launch/multiprocess.py — the process count and the
        # reduce shape are identity fields, so a wall-clock point never
        # silently matches an emulated one in compare.py
        "n_procs": (int, False),
        "overlapped": (bool, False),
        "update_interval": (int, False),
    },
    # replay-service throughput (benchmarks/fig_serve.py): sustained
    # insert and sample rates of the sharded rate-limited ReplayService
    # vs concurrent writer count — the planner's service-shape inputs
    # (runtime/planner.py select_replay_service).  realized_spi is
    # measurement-side (compare.py ignores it for identity).
    "serve": {
        **_COMMON_POINT,
        "inserts_per_s": ((int, float), True),
        "samples_per_s": ((int, float), True),
        "writers": (int, True),
        "n_shards": (int, True),
        "spi": ((int, float), True),       # configured samples-per-insert
        "batch_size": (int, True),
        "realized_spi": ((int, float), False),
        # recovery arm (fig_serve --fault, DESIGN.md §14): the server is
        # crashed mid-run and restored from shard snapshots.  fault and
        # outage_s (the deliberate downtime) are identity fields;
        # recovery_s (kill → first re-admitted append ack) is the arm's
        # measured quantity alongside the rate metrics.
        "fault": (bool, False),
        "outage_s": ((int, float), False),
        "recovery_s": ((int, float), False),
    },
    # actor-serve load generator (benchmarks/fig_actor.py): sustained
    # request rate + client latency of the continuous-batching inference
    # frontend (repro/serve) under N simulated users, with the mid-run
    # param-publication drill's p99 split.  Latencies and swap counts
    # are measurement-side (compare.py gates requests_per_s only).
    "actor": {
        **_COMMON_POINT,
        "requests_per_s": ((int, float), True),
        "users": (int, True),
        "target_rps": ((int, float), True),
        "overload": (bool, True),
        "slots": (int, True),
        "gen_tokens": (int, True),
        "arch": (str, True),
        "prompt_buckets": (str, True),
        "p50_ms": ((int, float), True),
        "p99_ms": ((int, float), True),
        "p99_before_swap_ms": ((int, float), False),
        "p99_after_swap_ms": ((int, float), False),
        "param_swaps": (int, False),
    },
    # replay-transaction microbenchmark (benchmarks/replay_micro.py)
    "replay": {
        **_COMMON_POINT,
        "replay_ops_per_s": ((int, float), True),
        "backend": (str, True),
        "mode": (str, True),        # "eager" | "lazy"
        "fused": (bool, True),      # fused sample+gather kernel arm
        "capacity": (int, True),
        "fanout": (int, True),
        "insert_batch": (int, True),
        "sample_batch": (int, True),
    },
}

PLAN_CONFIG_FIELDS: Dict[str, tuple] = {
    "backend": (str, True),
    "n_pods": (int, True),
    "n_data": (int, True),
    "publish_interval": (int, True),
    "max_staleness": (int, True),
    "compress_pod_reduce": (bool, True),
    # optional so hand-written pre-overlap plans stay loadable; every
    # planner-emitted plan carries it (PlannedConfig.to_dict)
    "overlap_pod_reduce": (bool, False),
    "n_envs": (int, True),
    "update_interval": (int, True),
    "x_actor": (int, True),
    "x_learner": (int, True),
    # replay-service degrees of freedom (DESIGN.md §11) — optional so
    # pre-service plans stay loadable; planner-emitted plans carry both
    "n_replay_shards": (int, False),
    "samples_per_insert": ((int, float), False),
    "predicted_env_steps_per_s": ((int, float), True),
    "source": (str, True),
}

METRIC = "env_steps_per_s"


class SchemaError(ValueError):
    """A BENCH payload that CI must not gate on."""


def _check_fields(obj: Dict[str, Any], fields: Dict[str, tuple],
                  where: str) -> None:
    if not isinstance(obj, dict):
        raise SchemaError(f"{where}: expected an object, got {type(obj).__name__}")
    for name, (types, required) in fields.items():
        if name not in obj:
            if required:
                raise SchemaError(f"{where}: missing required field {name!r}")
            continue
        val = obj[name]
        # bools pass isinstance(..., int); only admit them where declared
        if isinstance(val, bool) and types is not bool and bool not in (
                types if isinstance(types, tuple) else (types,)):
            raise SchemaError(
                f"{where}.{name}: expected {types}, got bool")
        if not isinstance(val, types):
            raise SchemaError(
                f"{where}.{name}: expected {types}, got {type(val).__name__} "
                f"({val!r})")
    unknown = set(obj) - set(fields)
    if unknown:
        raise SchemaError(f"{where}: unknown fields {sorted(unknown)}")


def validate(payload: Dict[str, Any]) -> str:
    """Validate one BENCH payload; returns its figure name.  Raises
    ``SchemaError`` with the offending path in the message."""
    if not isinstance(payload, dict):
        raise SchemaError(f"payload is {type(payload).__name__}, not an object")
    figure = payload.get("figure")
    if figure in POINT_FIELDS:
        metric = FIGURE_METRICS[figure]
        if payload.get("metric") != metric:
            raise SchemaError(f"{figure}: metric must be {metric!r}, got "
                              f"{payload.get('metric')!r}")
        points = payload.get("points")
        if not isinstance(points, list) or not points:
            raise SchemaError(f"{figure}: 'points' must be a non-empty list")
        for i, p in enumerate(points):
            _check_fields(p, POINT_FIELDS[figure], f"{figure}.points[{i}]")
            if p[metric] <= 0:
                raise SchemaError(
                    f"{figure}.points[{i}].{metric} must be > 0")
        return figure
    if figure == "plan":
        if payload.get("metric") != METRIC:
            raise SchemaError(f"plan: metric must be {METRIC!r}, got "
                              f"{payload.get('metric')!r}")
        _check_fields(payload.get("config"), PLAN_CONFIG_FIELDS, "plan.config")
        realized = payload.get("realized_env_steps_per_s")
        if realized is not None and not isinstance(realized, (int, float)):
            raise SchemaError("plan.realized_env_steps_per_s must be a "
                              "number or null")
        return figure
    raise SchemaError(f"unknown figure {figure!r} — expected one of "
                      f"{sorted(POINT_FIELDS) + ['plan']}")


def validate_file(path: str) -> str:
    with open(path) as f:
        try:
            payload = json.load(f)
        except json.JSONDecodeError as e:
            raise SchemaError(f"{path}: not valid json ({e})") from e
    try:
        return validate(payload)
    except SchemaError as e:
        raise SchemaError(f"{path}: {e}") from e


def main(argv: List[str]) -> int:
    if not argv:
        print("usage: python -m benchmarks.schema BENCH_*.json ...",
              file=sys.stderr)
        return 2
    for path in argv:
        figure = validate_file(path)
        print(f"OK {path} ({figure})")
    return 0


if __name__ == "__main__":
    try:
        sys.exit(main(sys.argv[1:]))
    except SchemaError as e:
        print(f"SCHEMA ERROR: {e}", file=sys.stderr)
        sys.exit(1)
