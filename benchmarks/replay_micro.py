"""Replay-transaction microbenchmark: the lazy-writing payoff, isolated.

Replay is the throughput ceiling of every executor backend (the paper's
§IV bottleneck analysis; Reverb and Spreeze reach the same conclusion),
so this benchmark times the *loop-shaped replay transaction* alone — one
iteration's worth of buffer work with the learner compute stripped out:

    insert_begin → [flush] → sample(+gather) → update_priorities
                 → insert_commit

swept over the axes the tentpole optimization changed:

  * ``mode``  — ``eager`` (each op propagates up the tree: three full
    passes per transaction, the pre-optimization baseline) vs ``lazy``
    (leaf-only writes + ONE merged propagation pass at the sample
    boundary, DESIGN.md §9);
  * ``fused`` — split sample + per-leaf gather kernels vs the fused
    sample+gather kernel (pallas backend only; the xla backend has no
    separate kernel launches to fuse);
  * ``backend`` — xla | pallas (interpret mode on CPU).

The metric is **replay ops/s**: transaction throughput × ops per
transaction (``insert_batch`` inserts + ``sample_batch`` samples +
``sample_batch`` priority updates), median-of-N with recorded dispersion
(benchmarks/timing.py).  ``--emit-json DIR`` writes ``BENCH_replay.json``
(schema: benchmarks/schema.py, figure "replay"); the committed repo-root
baseline is diffed by benchmarks/compare.py and must show the lazy mode
beating the eager mode per backend (asserted in
tests/test_replay_transactions.py).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp

from benchmarks.timing import REPEATS

REPLAY_JSON = "BENCH_replay.json"

# Per-backend sizing, chosen so the tree-propagation work (what lazy
# writing removes) is a visible fraction of the transaction on *that*
# backend: the XLA arms use a 64Ki-leaf tree (big enough that the three
# per-pass copies/scatters dominate fixed per-op costs — at a few Ki
# leaves the common-mode sample cost drowns the delta in runner noise);
# the pallas arms, which run in *interpret* mode on CPU, use an 8Ki
# tree (at 64Ki the interpreted descent matmuls dominate everything and
# no update-path difference is measurable).  Both fit the kernels' VMEM
# budget.  insert batch = capacity/512, sample batch = 2× that.
SIZES = {
    "xla": (65536, 128, 256),      # (capacity, insert_batch, sample_batch)
    "pallas": (8192, 64, 128),
}
OBS_DIM = 4           # cartpole-shaped transition payload


def _make_buffer(backend: str, fused: bool, fanout: int, capacity: int):
    from repro.core.replay import PrioritizedReplay, ReplayConfig

    example = {
        "obs": jnp.zeros((OBS_DIM,), jnp.float32),
        "action": jnp.zeros((), jnp.int32),
        "reward": jnp.zeros(()),
        "next_obs": jnp.zeros((OBS_DIM,), jnp.float32),
        "done": jnp.zeros(()),
    }
    rb = PrioritizedReplay(
        ReplayConfig(capacity=capacity, fanout=fanout, backend=backend,
                     fused_sample_gather=fused), example)
    return rb, example


def _transaction_scan(rb, example, lazy: bool, iters: int,
                      insert_batch: int, sample_batch: int):
    """``iters`` loop-shaped transactions inside one jitted ``lax.scan``
    (replay state donated) — the same execution shape as the executors'
    chunk programs, so per-call Python dispatch stays out of the
    measurement."""

    def txn(state, key):
        k_items, k_sample, k_td = jax.random.split(key, 3)
        state, slots = rb.insert_begin(state, insert_batch, lazy=lazy)
        if lazy:
            state = rb.flush(state)
        idx, items, w = rb.sample(state, k_sample, sample_batch)
        # thread a live (but negligible) dependency on the gathered items
        # and weights into the write-back so XLA cannot dead-code the
        # gather/weight computation out of the measured loop
        touch = 1e-12 * (jnp.mean(items["obs"]) + jnp.mean(w))
        td = jax.random.uniform(k_td, (sample_batch,), minval=0.01,
                                maxval=2.0) + touch
        state = rb.update_priorities(state, idx, td, lazy=lazy)
        fresh = jax.tree.map(
            lambda x: jax.random.normal(
                k_items, (insert_batch,) + tuple(x.shape)).astype(x.dtype),
            example)
        return rb.insert_commit(state, slots, fresh, lazy=lazy)

    def chunk(state, key):
        def body(s, i):
            return txn(s, jax.random.fold_in(key, i)), ()
        return jax.lax.scan(body, state, jnp.arange(iters))[0]

    return jax.jit(chunk, donate_argnums=(0,))


def _make_probe(backend: str, mode: str, fused: bool, iters: int,
                fanout: int):
    """Compile one arm's scanned transaction chunk and return a warmed
    ``probe() → replay ops/s`` closure."""
    capacity, insert_batch, sample_batch = SIZES[backend]
    rb, example = _make_buffer(backend, fused, fanout, capacity)
    chunk = _transaction_scan(rb, example, mode == "lazy", iters,
                              insert_batch, sample_batch)
    key = jax.random.PRNGKey(0)

    def fill(state):  # warm buffer: every slot valid, non-trivial tree
        return rb.insert(state, jax.tree.map(
            lambda x: jax.random.normal(
                key, (capacity,) + tuple(x.shape)).astype(x.dtype), example))

    state = fill(rb.init())
    state = chunk(state, key)                     # compile + cold pass
    jax.block_until_ready(state.tree)
    holder = [state, 0]

    def probe():
        holder[1] += 1
        t0 = time.perf_counter()
        holder[0] = chunk(holder[0], jax.random.fold_in(key, holder[1]))
        jax.block_until_ready(holder[0].tree)
        dt = time.perf_counter() - t0
        ops = insert_batch + 2 * sample_batch     # insert + sample + update
        return ops * iters / dt

    return probe


def replay_points(smoke: bool = False):
    """The committed sweep.

    Two comparisons ride in one payload:

      * **eager vs lazy** — like-for-like arms at ``fused=False`` per
        backend and fanout, where the propagation-pass difference is
        the dominant term.  The acceptance test
        (tests/test_replay_transactions.py) asserts lazy > eager on
        every such pair of the committed file;
      * **fused vs split** — the pallas sample+gather arms at fixed
        ``mode="lazy"``.  On CPU these run in Pallas *interpret* mode,
        where per-grid-step Python interpretation dominates — the
        fused-vs-split delta recorded here is qualitative (the HBM
        round trip it removes only matters compiled on TPU), so it is
        reported, not gated.
    """
    arms = [
        # (backend, mode, fused, fanout)
        ("xla", "eager", False, 64),
        ("xla", "lazy", False, 64),
        ("xla", "eager", False, 128),
        ("xla", "lazy", False, 128),
        ("pallas", "eager", False, 128),
        ("pallas", "lazy", False, 128),
        ("pallas", "lazy", True, 128),
    ]
    import statistics

    # compile + warm every arm first, then probe the arms round-robin:
    # background load on a shared runner drifts over minutes, so probing
    # arm-by-arm would hand different arms different machines — the
    # interleaving gives every arm the same load profile per round and
    # the per-arm median rejects the bursts
    probes = []
    for backend, mode, fused, fanout in arms:
        # sized so one scanned probe runs ≥ ~100ms (timer noise floor);
        # interpret-mode pallas is orders slower — keep its loop short
        iters = ((6 if backend == "pallas" else 500) if smoke
                 else (12 if backend == "pallas" else 2000))
        probe = _make_probe(backend, mode, fused, iters, fanout)
        probe()                                   # discard the warm-up pass
        probes.append(((backend, mode, fused, fanout), probe))
    samples = {key: [] for key, _ in probes}
    for _ in range(REPEATS):
        for key, probe in probes:
            samples[key].append(probe())

    points = []
    for (backend, mode, fused, fanout), vals in samples.items():
        ops_s = statistics.median(vals)
        spread = (max(vals) - min(vals)) / ops_s if ops_s > 0 else 0.0
        capacity, insert_batch, sample_batch = SIZES[backend]
        points.append({
            "backend": backend, "mode": mode, "fused": fused,
            "capacity": capacity, "fanout": fanout,
            "insert_batch": insert_batch, "sample_batch": sample_batch,
            "replay_ops_per_s": round(ops_s, 2),
            "repeats": REPEATS, "rel_spread": round(spread, 4),
        })
        print(f"# replay {backend}/K{fanout}/{mode}/fused={fused}: "
              f"{ops_s:,.0f} ops/s (±{spread:.1%})", file=sys.stderr)
    return points


def compiled_fused_record():
    """Attempt the fused sample+gather kernel *compiled* (non-interpret)
    on this host's default backend and record the outcome.

    Interpret mode inverts the fused kernel's advantage (the committed
    arms above: fused ≈ 4× slower than split on CPU), so the only fair
    measurement is a compiled one.  On TPU this returns a measured
    sample+gather rate; on CPU Pallas refuses to lower ("Only interpret
    mode is supported on CPU backend") and the record carries the error
    instead — which is exactly why ``ReplayConfig.fused_sample_gather``
    defaults to backend-appropriate
    (``tree_ops.default_fused_sample_gather``): fused only where it
    compiles.
    """
    from repro.core import sumtree
    from repro.kernels import ops as kops
    from repro.kernels import sample_gather as _ksg

    backend = jax.default_backend()
    capacity, _, sample_batch = SIZES["pallas"]
    spec = sumtree.make_spec(capacity, 128)
    key = jax.random.PRNGKey(0)
    tree = sumtree.update(
        spec, sumtree.init(spec),
        jnp.arange(capacity, dtype=jnp.int32),
        jax.random.uniform(key, (capacity,), minval=0.1, maxval=2.0),
        unique=True)
    storage = jax.random.normal(key, (capacity, OBS_DIM))
    bp = ((sample_batch + _ksg.SAMPLE_BLOCK - 1)
          // _ksg.SAMPLE_BLOCK) * _ksg.SAMPLE_BLOCK
    u = jax.random.uniform(jax.random.fold_in(key, 1), (bp,))
    np_ = ((capacity + _ksg.STORAGE_BLOCK - 1)
           // _ksg.STORAGE_BLOCK) * _ksg.STORAGE_BLOCK
    mat = jnp.pad(storage, ((0, np_ - capacity), (0, 0)))
    levels = kops.tree_to_levels(spec, tree)[1:]

    def call(interpret):
        idx, pri, (rows,) = _ksg.sample_gather_levels(
            levels, u, [mat], capacity=spec.capacity, fanout=spec.fanout,
            interpret=interpret)
        jax.block_until_ready(rows)
        return idx, pri, rows

    record = {"attempted_backend": backend}
    try:
        call(interpret=False)           # compile + cold pass
        samples = []
        for _ in range(REPEATS):
            t0 = time.perf_counter()
            call(interpret=False)
            samples.append(sample_batch / (time.perf_counter() - t0))
        samples.sort()
        record["compiled"] = True
        record["sample_gather_per_s"] = round(samples[len(samples) // 2], 2)
    except Exception as e:  # noqa: BLE001 — the refusal IS the result
        record["compiled"] = False
        record["error"] = f"{type(e).__name__}: {e}"[:300]
    return record


def emit_json(out_dir: str, smoke: bool = False) -> str:
    payload = {
        "figure": "replay",
        "metric": "replay_ops_per_s",
        "smoke": smoke,
        # top-level note (schema tolerates extra payload keys): the
        # compiled-vs-interpret resolution of the fused-kernel question
        "fused_compiled": compiled_fused_record(),
        "points": replay_points(smoke=smoke),
    }
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, REPLAY_JSON)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
        f.write("\n")
    print(f"# wrote {path} ({len(payload['points'])} points)",
          file=sys.stderr)
    return path


def run(csv=True):
    """CSV mode for the benchmarks.run harness."""
    rows = []
    for p in replay_points(smoke=True):
        name = (f"replay/{p['backend']}_K{p['fanout']}_{p['mode']}"
                + ("_fused" if p["fused"] else ""))
        rows.append((name, 1e6 / p["replay_ops_per_s"],
                     p["replay_ops_per_s"]))
    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.3f},{derived:.2f}")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--emit-json", default=None, metavar="DIR")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized iteration budget, same arms")
    args = ap.parse_args()
    if args.emit_json:
        emit_json(args.emit_json, smoke=args.smoke)
    else:
        print("name,us_per_call,derived")
        run()
