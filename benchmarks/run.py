# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness (deliverable d): one module per paper figure.

    fig8  — parallel framework vs sequential baseline (env-steps/s, speedup)
    fig9  — K-ary sum tree vs binary tree, fanout sweep (per-op µs, speedup)
    fig10 — DQN/DDPG/SAC scalability vs parallel actor lanes
    fig11 — our buffer plugged into a naive trainer (iteration µs, speedup)
    fig12 — DSE profile curves + Eq. 5 solution (realized ratio)
    roofline — §Roofline table from the dry-run artifacts (if present)

Run: PYTHONPATH=src python -m benchmarks.run [--only fig9,...]
"""

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. fig9,roofline")
    args = ap.parse_args()

    from benchmarks import (fig8_baseline, fig9_fanout, fig10_scalability,
                            fig11_plugin, fig12_dse, roofline)
    suites = {
        "fig8": fig8_baseline.run,
        "fig9": fig9_fanout.run,
        "fig10": fig10_scalability.run,
        "fig11": fig11_plugin.run,
        "fig12": fig12_dse.run,
        "roofline": roofline.run,
    }
    chosen = (args.only.split(",") if args.only else list(suites))
    print("name,us_per_call,derived")
    failed = []
    for name in chosen:
        try:
            suites[name](csv=True)
        except Exception:  # noqa: BLE001 — keep the harness sweeping
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
