# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness (deliverable d): one module per paper figure.

    fig8  — parallel framework vs sequential baseline (env-steps/s, speedup)
    fig9  — K-ary sum tree vs binary tree, fanout sweep (per-op µs, speedup)
    fig10 — DQN/DDPG/SAC scalability vs parallel actor lanes
    fig11 — our buffer plugged into a naive trainer (iteration µs, speedup)
    fig12 — DSE profile curves + Eq. 5 solution (realized ratio)
    roofline — §Roofline table from the dry-run artifacts (if present)

Run: PYTHONPATH=src python -m benchmarks.run [--only fig9,...]

Machine-readable perf trajectory: ``--emit-json DIR`` writes

    BENCH_fig9.json  — env-steps/s per runtime executor backend
                       (fused + async publish-interval sweep, in-process)
    BENCH_fig10.json — env-steps/s per shard/pod count (1-D data-axis
                       counts and 2-D pod×data points with and without
                       the int8-EF compressed cross-pod reduce; one
                       forced-device subprocess per point)

so CI and the roadmap can diff throughput across PRs instead of eyeballing
CSV.  ``--emit-json`` runs only the two executor sweeps (no tree/figure
suites) unless ``--only`` also names suites.
"""

import argparse
import json
import os
import sys
import traceback


def emit_json(out_dir: str) -> None:
    from benchmarks import fig9_fanout, fig10_scalability

    os.makedirs(out_dir, exist_ok=True)
    fig9 = {
        "figure": "fig9",
        "metric": "env_steps_per_s",
        "points": fig9_fanout.executor_backend_points(),
    }
    fig10 = {
        "figure": "fig10",
        "metric": "env_steps_per_s",
        "points": fig10_scalability.shard_pod_points(),
    }
    for name, payload in (("BENCH_fig9.json", fig9),
                          ("BENCH_fig10.json", fig10)):
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"# wrote {path} ({len(payload['points'])} points)",
              file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. fig9,roofline")
    ap.add_argument("--emit-json", default=None, metavar="DIR",
                    help="write BENCH_fig9.json / BENCH_fig10.json "
                         "(env-steps/s per executor backend and shard/pod "
                         "count) into DIR")
    args = ap.parse_args()

    failed = []
    if args.emit_json:
        try:
            emit_json(args.emit_json)
        except Exception:  # noqa: BLE001 — keep the harness sweeping
            failed.append("emit-json")
            traceback.print_exc()

    if args.only or not args.emit_json:
        from benchmarks import (fig8_baseline, fig9_fanout, fig10_scalability,
                                fig11_plugin, fig12_dse, roofline)
        suites = {
            "fig8": fig8_baseline.run,
            "fig9": fig9_fanout.run,
            "fig10": fig10_scalability.run,
            "fig11": fig11_plugin.run,
            "fig12": fig12_dse.run,
            "roofline": roofline.run,
        }
        chosen = (args.only.split(",") if args.only else list(suites))
        print("name,us_per_call,derived")
        for name in chosen:
            try:
                suites[name](csv=True)
            except Exception:  # noqa: BLE001 — keep the harness sweeping
                failed.append(name)
                traceback.print_exc()
    if failed:
        print(f"# FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
