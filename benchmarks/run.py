# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness (deliverable d): one module per paper figure.

    fig8  — parallel framework vs sequential baseline (env-steps/s, speedup)
    fig9  — K-ary sum tree vs binary tree, fanout sweep (per-op µs, speedup)
    fig10 — DQN/DDPG/SAC scalability vs parallel actor lanes
    fig11 — our buffer plugged into a naive trainer (iteration µs, speedup)
    fig12 — DSE profile curves + Eq. 5 solution via the runtime planner
    replay — lazy-vs-eager / fused-vs-split replay-transaction ops/s
    roofline — §Roofline table from the dry-run artifacts (if present)

Run: PYTHONPATH=src python -m benchmarks.run [--only fig9,...]

Machine-readable perf trajectory: ``--emit-json DIR`` writes

    BENCH_fig9.json  — env-steps/s per runtime executor backend
                       (fused + async publish-interval sweep, in-process)
    BENCH_fig10.json — env-steps/s per shard/pod count (1-D data-axis
                       counts and 2-D pod×data points with and without
                       the int8-EF compressed cross-pod reduce; one
                       forced-device subprocess per point)
    BENCH_plan.json  — the runtime config the DSE planner
                       (runtime/planner.py) selected from those points,
                       with predicted vs realized env-steps/s and the
                       Eq. 5 lane curves it solved over
    BENCH_replay.json — replay-transaction ops/s per (backend, eager|
                       lazy, fused|split) arm (benchmarks/replay_micro)
    BENCH_serve.json — replay-service sustained insert/sample rates vs
                       concurrent writer count (benchmarks/fig_serve) —
                       the planner's service-shape inputs
    BENCH_actor.json — actor-serve load generator (benchmarks/fig_actor):
                       sustained requests/s + p50/p99 latency of the
                       continuous-batching inference frontend under N
                       simulated users, with the mid-run param-swap drill

Every point is a median-of-N repeat with its dispersion recorded
(benchmarks/timing.py — the groundwork for a blocking perf gate).

so CI and the roadmap can diff throughput across PRs instead of
eyeballing CSV — the json is validated by ``benchmarks/schema.py`` and
diffed against the committed repo-root baselines by
``benchmarks/compare.py``.  ``--emit-json`` runs only the executor
sweeps (no tree/figure suites) unless ``--only`` also names suites.
``--smoke`` shrinks every sweep to a CI-sized budget (fewer points,
fewer iterations) — same schema, same code paths.
"""

import argparse
import json
import os
import sys
import traceback


def emit_json(out_dir: str, smoke: bool = False,
              wallclock: bool = False) -> None:
    from benchmarks import fig10_scalability, fig_actor, fig_serve, replay_micro
    from repro.runtime import planner

    os.makedirs(out_dir, exist_ok=True)
    replay_micro.emit_json(out_dir, smoke=smoke)
    fig_serve.emit_json(out_dir, smoke=smoke)
    fig_actor.emit_json(out_dir, smoke=smoke)
    prof = planner.profile(smoke=smoke)
    fig10_points = list(prof["fig10_points"])
    if wallclock:
        # the real multi-process gang arm (DESIGN.md §10) — measured at
        # the same global env count as the emulated arms of this run so
        # the uniformity invariant below holds
        n_envs = fig10_points[0]["n_envs"] if fig10_points else 8
        fig10_points += fig10_scalability.wallclock_points(
            n_envs=n_envs, iters=20 if smoke else 40)
    fig10_scalability.assert_uniform_n_envs(fig10_points)
    fig9 = {
        "figure": "fig9",
        "metric": "env_steps_per_s",
        "smoke": smoke,
        "points": prof["fig9_points"],
    }
    fig10 = {
        "figure": "fig10",
        "metric": "env_steps_per_s",
        "smoke": smoke,
        "points": fig10_points,
    }
    for name, payload in ((planner.FIG9_JSON, fig9),
                          (planner.FIG10_JSON, fig10)):
        path = os.path.join(out_dir, name)
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
            f.write("\n")
        print(f"# wrote {path} ({len(payload['points'])} points)",
              file=sys.stderr)

    serve_points = []
    serve_path = os.path.join(out_dir, fig_serve.SERVE_JSON)
    if os.path.exists(serve_path):
        with open(serve_path) as f:
            serve_points = json.load(f).get("points", [])
    pc = planner.plan(
        prof["fig9_points"], fig10_points,
        serve_points=serve_points,
        actor_curve=prof["actor_curve"],
        learner_curve=prof["learner_curve"],
        source="emit-json")
    realized = fig10_scalability.realize_plan(pc, iters=40 if smoke else 120)
    plan_path = os.path.join(out_dir, planner.PLAN_JSON)
    planner.save_plan(
        pc, plan_path,
        realized_env_steps_per_s=round(realized, 2),
        curves={
            "actor": {str(k): round(v, 2)
                      for k, v in prof["actor_curve"].items()},
            "learner": {str(k): round(v, 2)
                        for k, v in prof["learner_curve"].items()},
        })
    print(f"# wrote {plan_path}: {pc.describe()}", file=sys.stderr)
    print(f"#   realized {realized:,.0f} env-steps/s "
          f"(predicted {pc.predicted_env_steps_per_s:,.0f})",
          file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset, e.g. fig9,roofline")
    ap.add_argument("--emit-json", default=None, metavar="DIR",
                    help="write BENCH_fig9.json / BENCH_fig10.json / "
                         "BENCH_plan.json (env-steps/s per executor "
                         "backend and shard/pod count, plus the planner-"
                         "selected config) into DIR")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized budget: fewer sweep points and "
                         "iterations, same schema and code paths")
    ap.add_argument("--wall-clock", action="store_true",
                    help="add the real multi-process gang arm to "
                         "BENCH_fig10.json (launch/multiprocess.py: one "
                         "OS process per worker, gloo collectives)")
    args = ap.parse_args()

    failed = []
    if args.emit_json:
        try:
            emit_json(args.emit_json, smoke=args.smoke,
                      wallclock=args.wall_clock)
        except Exception:  # noqa: BLE001 — keep the harness sweeping
            failed.append("emit-json")
            traceback.print_exc()

    if args.only or not args.emit_json:
        from benchmarks import (fig8_baseline, fig9_fanout, fig10_scalability,
                                fig11_plugin, fig12_dse, fig_actor, fig_serve,
                                replay_micro, roofline)
        suites = {
            "fig8": fig8_baseline.run,
            "fig9": fig9_fanout.run,
            "fig10": fig10_scalability.run,
            "fig11": fig11_plugin.run,
            "fig12": fig12_dse.run,
            "replay": replay_micro.run,
            "serve": fig_serve.run,
            "actor": fig_actor.run,
            "roofline": roofline.run,
        }
        chosen = (args.only.split(",") if args.only else list(suites))
        print("name,us_per_call,derived")
        for name in chosen:
            try:
                suites[name](csv=True)
            except Exception:  # noqa: BLE001 — keep the harness sweeping
                failed.append(name)
                traceback.print_exc()
    if failed:
        print(f"# FAILED suites: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
