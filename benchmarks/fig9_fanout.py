"""Fig. 9 — K-ary sum tree throughput vs binary tree, fanout sweep.

Reproduces the paper's experiment: "4 threads, each running sampling and
priority update on the shared replay buffer 1000 times" → here, batched
ops of the same total volume (4×1000 interleaved sample+update rounds),
jitted, against buffer sizes 1e3/1e4/1e5.  Speedup = binary-tree time /
K-ary time; the paper finds an optimal K per buffer size (cacheline
effect) — on TPU-lane layout the optimum sits at K=128/256 (DESIGN.md §2).
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sumtree
from repro.core.tree_ops import get_tree_ops

THREADS = 4
ROUNDS = 50            # jitted rounds; each round = sample+update batch
BATCH = THREADS * 25   # ops in flight per round


def bench_tree(capacity: int, fanout: int, backend: str = "xla") -> float:
    """Returns seconds per (sample+update) op through a TreeOps backend."""
    spec = sumtree.make_spec(capacity, fanout)
    rng = np.random.default_rng(0)
    pri = jnp.asarray(rng.uniform(0.1, 2.0, capacity).astype(np.float32))
    tree = sumtree.build(spec, pri)

    ops = get_tree_ops(backend)
    sample_fn = lambda t, u: ops.sample(spec, t, u)
    update_fn = lambda t, i, v: ops.update(spec, t, i, v)

    @jax.jit
    def round_(tree, key):
        k1, k2 = jax.random.split(key)
        u = jax.random.uniform(k1, (BATCH,))
        idx, pri = sample_fn(tree, u)
        new = jax.random.uniform(k2, (BATCH,), minval=0.05, maxval=2.0)
        return update_fn(tree, idx, new)

    key = jax.random.PRNGKey(0)
    tree = round_(tree, key)  # compile
    tree.block_until_ready()
    t0 = time.perf_counter()
    for i in range(ROUNDS):
        tree = round_(tree, jax.random.fold_in(key, i))
    tree.block_until_ready()
    dt = time.perf_counter() - t0
    return dt / (ROUNDS * BATCH)


def run(csv=True):
    rows = []
    for capacity in (1_000, 10_000, 100_000):
        base = bench_tree(capacity, 2)
        rows.append((f"fig9/binary_N{capacity}", base * 1e6, 1.0))
        for k in (4, 16, 64, 128, 256):
            t = bench_tree(capacity, k)
            rows.append((f"fig9/K{k}_N{capacity}", t * 1e6, base / t))
    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.3f},{derived:.2f}")
    return rows


if __name__ == "__main__":
    run()
