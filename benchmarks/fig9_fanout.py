"""Fig. 9 — K-ary sum tree throughput vs binary tree, fanout sweep,
plus the runtime-backend fan-out sweep (``--executor``).

Tree mode (default) reproduces the paper's experiment: "4 threads, each
running sampling and priority update on the shared replay buffer 1000
times" → here, batched ops of the same total volume (4×1000 interleaved
sample+update rounds), jitted, against buffer sizes 1e3/1e4/1e5.
Speedup = binary-tree time / K-ary time; the paper finds an optimal K
per buffer size (cacheline effect) — on TPU-lane layout the optimum sits
at K=128/256 (DESIGN.md §2).

Executor mode sweeps the third runtime backend (DESIGN.md §5)::

    # fused async: publish-interval sweep vs the synchronous baseline
    python benchmarks/fig9_fanout.py --executor async

    # sharded async: staleness-weighted reduce, max-staleness sweep
    python benchmarks/fig9_fanout.py --executor async --shards 4 \\
        --max-staleness 0,1,3

reporting env-steps/s per (publish_interval, max_staleness) point and
the speedup over the synchronous executor at the same shard count
(``max_staleness`` only shapes the sharded gradient reduce — without
``--shards`` it is inert and the sweep collapses to publish_interval).
"""

import argparse
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sumtree
from repro.core.tree_ops import get_tree_ops

THREADS = 4
ROUNDS = 50            # jitted rounds; each round = sample+update batch
BATCH = THREADS * 25   # ops in flight per round


def bench_tree(capacity: int, fanout: int, backend: str = "xla") -> float:
    """Returns seconds per (sample+update) op through a TreeOps backend."""
    spec = sumtree.make_spec(capacity, fanout)
    rng = np.random.default_rng(0)
    pri = jnp.asarray(rng.uniform(0.1, 2.0, capacity).astype(np.float32))
    tree = sumtree.build(spec, pri)

    ops = get_tree_ops(backend)
    sample_fn = lambda t, u: ops.sample(spec, t, u)
    update_fn = lambda t, i, v: ops.update(spec, t, i, v)

    @jax.jit
    def round_(tree, key):
        k1, k2 = jax.random.split(key)
        u = jax.random.uniform(k1, (BATCH,))
        idx, pri = sample_fn(tree, u)
        new = jax.random.uniform(k2, (BATCH,), minval=0.05, maxval=2.0)
        return update_fn(tree, idx, new)

    key = jax.random.PRNGKey(0)
    tree = round_(tree, key)  # compile
    tree.block_until_ready()
    t0 = time.perf_counter()
    for i in range(ROUNDS):
        tree = round_(tree, jax.random.fold_in(key, i))
    tree.block_until_ready()
    dt = time.perf_counter() - t0
    return dt / (ROUNDS * BATCH)


def run(csv=True):
    rows = []
    for capacity in (1_000, 10_000, 100_000):
        base = bench_tree(capacity, 2)
        rows.append((f"fig9/binary_N{capacity}", base * 1e6, 1.0))
        for k in (4, 16, 64, 128, 256):
            t = bench_tree(capacity, k)
            rows.append((f"fig9/K{k}_N{capacity}", t * 1e6, base / t))
    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.3f},{derived:.2f}")
    return rows


# -- executor fan-out sweep (runtime backends, DESIGN.md §3/§5) --------------


def _make_runtime_executor(kind, n_envs, shards, publish_interval,
                           max_staleness, scan_chunk=20, pods=0,
                           compress=False):
    """Build any runtime-backend executor for a throughput measurement:
    ``kind`` ∈ {fused, sharded, async}; ``shards`` is the data-axis
    extent (0 = no mesh), ``pods`` adds the slow pod axis (needs
    ``pods × shards`` forced devices), ``compress`` switches the
    cross-pod reduce to the int8-EF compressed mean.  This is also the
    generic worker behind ``planner``-chosen configs
    (fig10_scalability's ``--_plan-worker`` / benchmarks/run.py)."""
    import functools

    from repro.agents.dqn import DQNConfig, make_dqn
    from repro.core.replay import PrioritizedReplay, ReplayConfig
    from repro.envs.classic import make_vec
    from repro.runtime.executors import (AsyncExecutor, FusedExecutor,
                                         ShardedExecutor)
    from repro.runtime.loop import LoopConfig

    env_fn = functools.partial(make_vec, "cartpole")
    spec, _, _ = env_fn(1)
    agent = make_dqn(spec, DQNConfig())
    example = {
        "obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "action": jnp.zeros((), jnp.int32),
        "reward": jnp.zeros(()),
        "next_obs": jnp.zeros((spec.obs_dim,), jnp.float32),
        "done": jnp.zeros(()),
    }
    cfg = LoopConfig(batch_size=64, warmup=64, epsilon=0.1)
    if shards:
        from repro.core.distributed import (ShardedPrioritizedReplay,
                                            ShardedReplayConfig)
        from repro.launch.mesh import data_mesh, pod_data_mesh

        n_cells = shards * max(1, pods)
        axis_names = ("pod", "data") if pods else ("data",)
        replay = ShardedPrioritizedReplay(
            ShardedReplayConfig(capacity_per_shard=50_000 // n_cells,
                                fanout=128, axis_names=axis_names), example)
        mesh = pod_data_mesh(pods, shards) if pods else data_mesh(shards)
        if kind == "async":
            return AsyncExecutor(agent, replay, env_fn, cfg, n_envs,
                                 publish_interval=publish_interval,
                                 max_staleness=max_staleness, mesh=mesh,
                                 scan_chunk=scan_chunk,
                                 compress_pod_reduce=compress)
        return ShardedExecutor(agent, replay, env_fn, cfg, n_envs, mesh,
                               scan_chunk=scan_chunk,
                               compress_pod_reduce=compress)
    replay = PrioritizedReplay(ReplayConfig(capacity=50_000, fanout=128),
                               example)
    if kind == "async":
        return AsyncExecutor(agent, replay, env_fn, cfg, n_envs,
                             publish_interval=publish_interval,
                             max_staleness=max_staleness,
                             scan_chunk=scan_chunk)
    return FusedExecutor(agent, replay, env_fn, cfg, n_envs,
                         scan_chunk=scan_chunk)


def plan_throughput(plan, iters=120):
    """env-steps/s of a planner-selected config (the realized side of
    BENCH_plan.json's predicted-vs-realized record).  Must run inside a
    process whose forced device count ≥ ``plan.n_devices``."""
    ex = _make_runtime_executor(
        plan.backend, plan.n_envs, plan.n_data, plan.publish_interval,
        plan.max_staleness, pods=plan.n_pods if plan.n_pods > 1 else 0,
        compress=plan.compress_pod_reduce)
    return _steps_per_s(ex, iters=iters)


def _steps_per_s_stats(ex, iters=120, repeats=None):
    """(median env-steps/s, rel_spread) over ``repeats`` measurement
    passes of one warmed executor (benchmarks/timing.py policy)."""
    from benchmarks.timing import REPEATS, median_with_spread

    st = ex.init(jax.random.PRNGKey(0))
    st, _ = ex.run_chunk(st)
    jax.block_until_ready(st.obs)
    n_chunks = max(1, iters // ex.scan_chunk)
    state = [st]

    def probe():
        t0 = time.perf_counter()
        for _ in range(n_chunks):
            state[0], _ = ex.run_chunk(state[0])
        jax.block_until_ready(state[0].obs)
        return ex.n_envs * ex.scan_chunk * n_chunks / (time.perf_counter() - t0)

    return median_with_spread(probe, REPEATS if repeats is None else repeats)


def _steps_per_s(ex, iters=120):
    """Single-shot env-steps/s (no repeats) — kept for quick sweeps."""
    return _steps_per_s_stats(ex, iters=iters, repeats=1)[0]


def run_executor_sweep(publish_intervals, max_stalenesses, n_envs=8,
                       shards=0, csv=True):
    """Async backend sweep: env-steps/s per (publish_interval,
    max_staleness) point vs the synchronous executor at equal shards."""
    tag = f"{shards}shards" if shards else "fused"
    base_kind = "sharded" if shards else "fused"
    rows = []
    base = _steps_per_s(_make_runtime_executor(base_kind, n_envs, shards, 0, 0))
    rows.append((f"fig9/{base_kind}_sync_{tag}", 1e6 / base, 1.0))
    if not shards:
        max_stalenesses = max_stalenesses[:1]   # inert without a reduce
    for p in publish_intervals:
        for s in max_stalenesses:
            t = _steps_per_s(_make_runtime_executor(
                "async", n_envs, shards, p, s))
            rows.append((f"fig9/async_p{p}_s{s}_{tag}", 1e6 / t, t / base))
    if csv:
        for name, us, derived in rows:
            print(f"{name},{us:.2f},{derived:.2f}")
    return rows


def executor_backend_points(publish_intervals=(1, 2, 4), n_envs=8, iters=120):
    """Machine-readable env-steps/s per runtime backend (the in-process
    slice of BENCH_fig9.json — the shard/pod axis rides in fig10's
    subprocess sweep, since the forced device count must be set before
    jax initializes).  Each point is the median of N repeats with the
    dispersion recorded (benchmarks/timing.py)."""
    from benchmarks.timing import REPEATS

    points = []
    base, spread = _steps_per_s_stats(
        _make_runtime_executor("fused", n_envs, 0, 0, 0), iters=iters)
    points.append({"backend": "fused", "shards": 0, "pods": 1,
                   "publish_interval": 0, "max_staleness": 0,
                   "n_envs": n_envs, "env_steps_per_s": round(base, 2),
                   "speedup_vs_sync": 1.0,
                   "repeats": REPEATS, "rel_spread": round(spread, 4)})
    for p in publish_intervals:
        t, spread = _steps_per_s_stats(
            _make_runtime_executor("async", n_envs, 0, p, 0), iters=iters)
        points.append({"backend": "async", "shards": 0, "pods": 1,
                       "publish_interval": p, "max_staleness": 0,
                       "n_envs": n_envs, "env_steps_per_s": round(t, 2),
                       "speedup_vs_sync": round(t / base, 3),
                       "repeats": REPEATS, "rel_spread": round(spread, 4)})
    return points


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--executor", choices=("tree", "fused", "async"),
                    default="tree",
                    help="tree = the paper's Fig. 9 fanout sweep; "
                         "fused/async = runtime-backend throughput sweep")
    ap.add_argument("--publish-interval", default="1,2,4,8",
                    help="comma list of actor-copy publish intervals")
    ap.add_argument("--max-staleness", default="0,1,3",
                    help="comma list of staleness bounds for the sharded "
                         "async gradient reduce")
    ap.add_argument("--shards", type=int, default=0,
                    help="sweep over this many forced host-platform device "
                         "shards (sharded async backend)")
    ap.add_argument("--n-envs", type=int, default=8)
    args = ap.parse_args()
    if args.shards:
        # the backend reads XLA_FLAGS at first use, which nothing in this
        # module triggers at import time — set it before any jax call
        import re

        existing = os.environ.get("XLA_FLAGS", "")
        m = re.search(r"--xla_force_host_platform_device_count=(\d+)",
                      existing)
        if m and int(m.group(1)) != args.shards:
            raise SystemExit(
                "XLA_FLAGS already pins "
                f"{m.group(1)} host devices, conflicting with "
                f"--shards {args.shards}; unset it or make them agree")
        if not m:
            flag = f"--xla_force_host_platform_device_count={args.shards}"
            os.environ["XLA_FLAGS"] = f"{existing} {flag}".strip()
    if args.executor == "tree":
        run()
    else:
        # --executor fused benchmarks only the synchronous baseline row
        run_executor_sweep(
            ([int(x) for x in args.publish_interval.split(",")]
             if args.executor == "async" else []),
            [int(x) for x in args.max_staleness.split(",")],
            n_envs=args.n_envs, shards=args.shards)
