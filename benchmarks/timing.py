"""Shared measurement policy for the BENCH json emitters.

Every emitted point is the **median of N repeats** with the dispersion
recorded next to it (``rel_spread = (max - min) / median``), so the
perf-regression gate (benchmarks/compare.py) can tell structural
slowdowns from runner jitter — the groundwork for promoting the >30%
gate to blocking.  The repeat count is deliberately one number for the
whole suite: CI and local runs produce comparable dispersion.
"""

from __future__ import annotations

import statistics
from typing import Callable, Tuple

# repeats per emitted point (median-of-N); the warmed-up measurement
# loop is cheap next to jit compilation, so N=3 costs little wall time
REPEATS = 3


def median_with_spread(measure: Callable[[], float],
                       repeats: int = REPEATS) -> Tuple[float, float]:
    """Run ``measure`` (a warmed-up throughput probe returning a rate)
    ``repeats`` times; returns (median, rel_spread)."""
    vals = [float(measure()) for _ in range(max(1, repeats))]
    med = statistics.median(vals)
    spread = (max(vals) - min(vals)) / med if med > 0 else 0.0
    return med, spread
